//! IVFFlat: k-means centroids + inverted posting lists over embedding
//! rows, with a brute-force exhaustive path that doubles as the exact
//! oracle.
//!
//! Exactness contract (pinned by `tests/ann.rs`):
//! - Every path computes distances with the ONE [`l2_distance`]
//!   function over the same stored rows, so any path that *considers*
//!   a row reports a bitwise-identical distance for it.
//! - Neighbors are ordered by the total order [`neighbor_cmp`]
//!   (distance, then key), so result order is deterministic even under
//!   distance ties (duplicate rows).
//! - At `probe >= 1.0` — or below the `min_brute` size threshold — the
//!   query short-circuits to the exhaustive scan, which considers every
//!   row: ids and distances are exactly the brute-force oracle's.

use std::cmp::Ordering;

use crate::store::{CacheKey, RowData};

use super::kmeans::lloyd_rows;

/// Default fraction of posting lists scanned per query.
pub const DEFAULT_PROBE: f64 = 0.25;
/// Below this many indexed rows, every query brute-force scans.
pub const DEFAULT_MIN_BRUTE: usize = 64;
/// Upper bound on the centroid count (`nlist = min(⌊√n⌋, cap)`).
pub const DEFAULT_CENTROID_CAP: usize = 256;
/// Lloyd's iteration budget per build.
pub const DEFAULT_KMEANS_ITERS: usize = 12;
/// Pending-tail length that triggers a background index rebuild.
pub const DEFAULT_REBUILD_PENDING: usize = 256;

/// Build/query parameters for the IVF index.
#[derive(Clone, Debug)]
pub struct AnnConfig {
    /// Fraction of posting lists scanned per query, in (0, 1]. At 1.0
    /// the scan is exhaustive (exact).
    pub probe_factor: f64,
    /// Brute-force threshold: indexes smaller than this skip the IVF
    /// machinery entirely.
    pub min_brute: usize,
    /// Cap on the centroid count.
    pub centroid_cap: usize,
    /// Lloyd's iteration budget.
    pub kmeans_iters: usize,
    /// k-means init seed.
    pub seed: u64,
    /// Pending-tail length that triggers a rebuild (used by the serve
    /// cache, carried here so one struct travels the stack).
    pub rebuild_pending: usize,
}

impl Default for AnnConfig {
    fn default() -> Self {
        AnnConfig {
            probe_factor: DEFAULT_PROBE,
            min_brute: DEFAULT_MIN_BRUTE,
            centroid_cap: DEFAULT_CENTROID_CAP,
            kmeans_iters: DEFAULT_KMEANS_ITERS,
            seed: 0x1DF_F1A7,
            rebuild_pending: DEFAULT_REBUILD_PENDING,
        }
    }
}

/// One retrieval hit: a stored key and its exact L2 distance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub key: CacheKey,
    pub distance: f32,
}

/// Result of one index query, with scan-effort counters for `stats`.
#[derive(Clone, Debug, Default)]
pub struct AnnQuery {
    /// Up to k neighbors in `(distance, key)` order.
    pub neighbors: Vec<Neighbor>,
    /// Posting lists scanned (0 on the brute-force path).
    pub probed: usize,
    /// Rows whose distance was computed.
    pub scanned: usize,
}

/// Exact L2 distance: f64-accumulated squared diffs, one sqrt, rounded
/// once to f32. This is the single distance function for every path —
/// IVF, brute force, and the pending-tail scan — which is what makes
/// "bitwise-equal distances" a meaningful cross-path contract.
pub fn l2_distance(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = f64::from(x) - f64::from(y);
        acc += d * d;
    }
    acc.sqrt() as f32
}

/// Total order on neighbors: distance first (IEEE total order, so ties
/// and specials are deterministic), then key. Keys are unique within an
/// index, so the order is strict.
pub fn neighbor_cmp(a: &Neighbor, b: &Neighbor) -> Ordering {
    a.distance.total_cmp(&b.distance).then_with(|| a.key.cmp(&b.key))
}

/// Immutable IVFFlat index over a snapshot of store rows. Rebuilt from
/// scratch on store open / compaction / pending-tail overflow; queries
/// share it behind an `Arc`.
#[derive(Debug)]
pub struct AnnIndex {
    cfg: AnnConfig,
    dim: usize,
    /// Row keys, ascending — `rows[i]` belongs to `keys[i]`.
    keys: Vec<CacheKey>,
    /// The indexed rows, referenced in place: zero-copy views into
    /// mapped sealed segments when the store feed provides them, owned
    /// copies only for active-tail rows and legacy callers. Views pin
    /// their segment mappings (`Arc`), so this index stays valid after
    /// compaction deletes the files it was built from — that is the
    /// atomic generation swap.
    rows: Vec<RowData>,
    /// Flat `nlist × dim` centroids.
    centroids: Vec<f32>,
    /// Per-centroid posting lists of row indices.
    lists: Vec<Vec<u32>>,
    /// Entries dropped at build time (row length != dim).
    skipped: usize,
}

impl AnnIndex {
    /// Build an index over `entries` — owned rows (`Vec<f32>`) or
    /// zero-copy [`RowData`] views, anything `Into<RowData>`. Rows
    /// whose length differs from `dim` are dropped (counted in
    /// [`AnnIndex::skipped`]); duplicate keys keep their first row.
    /// Entries are sorted by key so the build is a pure function of
    /// (row set, cfg) regardless of input order — store snapshots and
    /// in-memory corpora build bitwise-identical indexes, and (via the
    /// accessor-generic [`lloyd_rows`]) view-backed and copy-backed
    /// feeds cluster bitwise identically too.
    pub fn build<R: Into<RowData>>(
        entries: Vec<(CacheKey, R)>,
        dim: usize,
        cfg: &AnnConfig,
    ) -> AnnIndex {
        let mut entries: Vec<(CacheKey, RowData)> =
            entries.into_iter().map(|(k, r)| (k, r.into())).collect();
        let mut skipped = 0usize;
        entries.retain(|(_, row)| {
            let ok = dim > 0 && row.len() == dim;
            if !ok {
                skipped += 1;
            }
            ok
        });
        entries.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        entries.dedup_by(|a, b| a.0 == b.0);

        let n = entries.len();
        let mut keys = Vec::with_capacity(n);
        let mut rows = Vec::with_capacity(n);
        for (key, row) in entries {
            keys.push(key);
            rows.push(row);
        }

        let (centroids, lists) = if n == 0 {
            (Vec::new(), Vec::new())
        } else {
            let nlist = isqrt(n).clamp(1, cfg.centroid_cap.max(1)).min(n);
            let km =
                lloyd_rows(n, dim, |i| rows[i].as_slice(), nlist, cfg.seed, cfg.kmeans_iters);
            let mut lists = vec![Vec::new(); nlist];
            for (i, &a) in km.assign.iter().enumerate() {
                lists[a as usize].push(i as u32);
            }
            (km.centroids, lists)
        };

        AnnIndex { cfg: cfg.clone(), dim, keys, rows, centroids, lists, skipped }
    }

    /// k nearest stored rows. Dispatch: exhaustive scan at
    /// `probe >= 1.0` or below the `min_brute` threshold, IVF probing
    /// otherwise. Returns `min(k, len)` neighbors.
    pub fn nearest(&self, query: &[f32], k: usize, probe: f64) -> AnnQuery {
        if probe >= 1.0 || self.keys.len() < self.cfg.min_brute {
            self.nearest_brute(query, k)
        } else {
            self.nearest_ivf(query, k, probe)
        }
    }

    /// Exhaustive scan: every row, exact distances. This is the oracle
    /// the differential battery holds the IVF path to.
    pub fn nearest_brute(&self, query: &[f32], k: usize) -> AnnQuery {
        let n = self.keys.len();
        let neighbors = self.select_k(0..n as u32, query, k);
        AnnQuery { neighbors, probed: 0, scanned: n }
    }

    /// IVF probe: rank centroids by distance to the query, scan the
    /// `⌈probe · nlist⌉` nearest posting lists. Exposed (not just
    /// `nearest`) so tests can pin that the IVF machinery itself — not
    /// merely the dispatch short-circuit — is exact at probe 1.0.
    pub fn nearest_ivf(&self, query: &[f32], k: usize, probe: f64) -> AnnQuery {
        let nlist = self.lists.len();
        if nlist == 0 {
            return AnnQuery::default();
        }
        // Rank centroids by (distance, index): deterministic under ties.
        let mut order: Vec<(f32, u32)> = self
            .centroids
            .chunks_exact(self.dim)
            .enumerate()
            .map(|(c, cent)| (l2_distance(query, cent), c as u32))
            .collect();
        order.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        let nprobe = ((probe * nlist as f64).ceil() as usize).clamp(1, nlist);
        let mut candidates: Vec<u32> = Vec::new();
        for &(_, c) in order.iter().take(nprobe) {
            candidates.extend_from_slice(&self.lists[c as usize]);
        }
        let scanned = candidates.len();
        let neighbors = self.select_k(candidates.into_iter(), query, k);
        AnnQuery { neighbors, probed: nprobe, scanned }
    }

    /// Shared tail of every path: exact distances for the candidate
    /// rows, `(distance, key)` sort, truncate to k.
    fn select_k(
        &self,
        candidates: impl Iterator<Item = u32>,
        query: &[f32],
        k: usize,
    ) -> Vec<Neighbor> {
        let mut neighbors: Vec<Neighbor> = candidates
            .map(|i| {
                let i = i as usize;
                Neighbor {
                    key: self.keys[i],
                    distance: l2_distance(query, self.rows[i].as_slice()),
                }
            })
            .collect();
        neighbors.sort_unstable_by(neighbor_cmp);
        neighbors.truncate(k);
        neighbors
    }

    /// Whether `key` is covered by this index (used to prune the serve
    /// cache's pending tail after a rebuild).
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.keys.binary_search(key).is_ok()
    }

    /// Number of indexed rows.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Row dimensionality this index was built for.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Posting-list (= centroid) count.
    pub fn nlist(&self) -> usize {
        self.lists.len()
    }

    /// Entries dropped at build time for having the wrong row length.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Heap bytes this index *owns* for row storage. Zero-copy views
    /// own nothing, so an index built over a fully sealed mmap'd store
    /// reports ≈ 0 — the RSS-proxy assert that pins "the ANN build no
    /// longer copies every row".
    pub fn indexed_bytes(&self) -> u64 {
        self.rows.iter().map(|r| r.owned_bytes() as u64).sum()
    }
}

/// ⌊√n⌋ without pulling in integer-sqrt from unstable std. Exact for
/// every n this index will ever see (f64 is exact below 2^53).
fn isqrt(n: usize) -> usize {
    (n as f64).sqrt().floor() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn key(i: u64) -> CacheKey {
        CacheKey { graph_hash: i.wrapping_mul(0x9E37_79B9_7F4A_7C15), config_fp: 0xC0FFEE, seed: i }
    }

    fn corpus(n: usize, dim: usize, seed: u64) -> Vec<(CacheKey, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let mut row = vec![0.0f32; dim];
                rng.fill_gaussian(&mut row, 1.0);
                (key(i as u64), row)
            })
            .collect()
    }

    #[test]
    fn empty_index_answers_empty() {
        let idx = AnnIndex::build(Vec::<(CacheKey, Vec<f32>)>::new(), 8, &AnnConfig::default());
        assert!(idx.is_empty());
        assert_eq!(idx.nlist(), 0);
        let q = idx.nearest(&[0.0; 8], 5, 1.0);
        assert!(q.neighbors.is_empty());
        let q = idx.nearest_ivf(&[0.0; 8], 5, 0.25);
        assert!(q.neighbors.is_empty());
    }

    #[test]
    fn tiny_store_clamps_to_a_single_list() {
        for n in [1usize, 2, 3] {
            let idx = AnnIndex::build(corpus(n, 6, 9), 6, &AnnConfig::default());
            assert_eq!(idx.len(), n);
            // isqrt(1..=3) == 1: everything lands in one posting list.
            assert_eq!(idx.nlist(), 1, "n={n}");
            let q = idx.nearest_ivf(&[0.0; 6], n, 0.01);
            assert_eq!(q.probed, 1);
            assert_eq!(q.neighbors.len(), n);
        }
    }

    #[test]
    fn centroid_cap_bounds_the_list_count() {
        let cfg = AnnConfig { centroid_cap: 4, ..AnnConfig::default() };
        let idx = AnnIndex::build(corpus(100, 4, 3), 4, &cfg);
        assert_eq!(idx.nlist(), 4, "isqrt(100)=10 must clamp to cap=4");
    }

    #[test]
    fn wrong_dim_rows_are_skipped_not_indexed() {
        let mut entries = corpus(5, 8, 21);
        entries.push((key(100), vec![0.0; 3]));
        entries.push((key(101), Vec::new()));
        let idx = AnnIndex::build(entries, 8, &AnnConfig::default());
        assert_eq!(idx.len(), 5);
        assert_eq!(idx.skipped(), 2);
        assert!(!idx.contains(&key(100)));
    }

    #[test]
    fn duplicate_keys_keep_one_row() {
        let mut entries = corpus(4, 4, 31);
        let dup = entries[2].clone();
        entries.push(dup);
        let idx = AnnIndex::build(entries, 4, &AnnConfig::default());
        assert_eq!(idx.len(), 4);
    }

    #[test]
    fn self_query_returns_itself_at_distance_zero() {
        let entries = corpus(50, 16, 77);
        let idx = AnnIndex::build(entries.clone(), 16, &AnnConfig::default());
        for (k, row) in &entries {
            let q = idx.nearest(row, 1, 1.0);
            assert_eq!(q.neighbors[0].key, *k);
            assert_eq!(q.neighbors[0].distance.to_bits(), 0.0f32.to_bits());
        }
    }

    #[test]
    fn probe_is_clamped_to_at_least_one_list() {
        // 100 rows ≥ min_brute is not guaranteed here, so call the IVF
        // path directly: even a vanishing probe factor scans one list.
        let idx = AnnIndex::build(corpus(100, 8, 5), 8, &AnnConfig::default());
        let q = idx.nearest_ivf(&[0.0; 8], 3, 1e-9);
        assert_eq!(q.probed, 1);
        let q = idx.nearest_ivf(&[0.0; 8], 3, 5.0);
        assert_eq!(q.probed, idx.nlist());
    }

    #[test]
    fn brute_dispatch_below_min_brute_and_at_probe_one() {
        let cfg = AnnConfig { min_brute: 64, ..AnnConfig::default() };
        let small = AnnIndex::build(corpus(20, 8, 13), 8, &cfg);
        let q = small.nearest(&[0.0; 8], 5, 0.1);
        assert_eq!((q.probed, q.scanned), (0, 20), "below min_brute must brute-scan");
        let large = AnnIndex::build(corpus(80, 8, 13), 8, &cfg);
        let q = large.nearest(&[0.0; 8], 5, 1.0);
        assert_eq!((q.probed, q.scanned), (0, 80), "probe 1.0 must brute-scan");
        let q = large.nearest(&[0.0; 8], 5, 0.25);
        assert!(q.probed > 0, "above min_brute at probe<1 must take the IVF path");
    }

    #[test]
    fn view_backed_build_is_bitwise_the_vec_backed_build_and_owns_nothing() {
        use crate::store::{RowView, SegmentMap};
        use std::sync::Arc;

        let (n, dim) = (40usize, 8usize);
        let cfg = AnnConfig::default();
        let entries = corpus(n, dim, 0xFEED);
        // Lay the rows out in one file exactly as a sealed segment
        // would (4-aligned f32 LE bits) and build from views into it.
        let mut bytes = Vec::new();
        for (_, row) in &entries {
            for v in row {
                bytes.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        let path = std::env::temp_dir()
            .join(format!("graphlet_ivf_view_{}", std::process::id()));
        std::fs::write(&path, &bytes).unwrap();
        let map = Arc::new(SegmentMap::map(&path).unwrap());
        let _ = std::fs::remove_file(&path);
        let view_entries: Vec<(CacheKey, RowData)> = entries
            .iter()
            .enumerate()
            .map(|(i, (k, row))| match RowView::new(Arc::clone(&map), i * dim * 4, dim) {
                Some(v) => (*k, RowData::View(v)),
                // Big-endian fallback: the comparison below still holds.
                None => (*k, RowData::Owned(row.clone())),
            })
            .collect();

        let owned_idx = AnnIndex::build(entries.clone(), dim, &cfg);
        let view_idx = AnnIndex::build(view_entries, dim, &cfg);
        assert_eq!(owned_idx.indexed_bytes(), (n * dim * 4) as u64);
        if cfg!(target_endian = "little") {
            assert_eq!(view_idx.indexed_bytes(), 0, "a view-backed index owns no row bytes");
        }
        assert_eq!(owned_idx.nlist(), view_idx.nlist());
        for (_, qrow) in entries.iter().take(8) {
            for probe in [0.25, 1.0] {
                let a = owned_idx.nearest(qrow, 5, probe);
                let b = view_idx.nearest(qrow, 5, probe);
                assert_eq!((a.probed, a.scanned), (b.probed, b.scanned));
                let abits: Vec<(CacheKey, u32)> =
                    a.neighbors.iter().map(|nb| (nb.key, nb.distance.to_bits())).collect();
                let bbits: Vec<(CacheKey, u32)> =
                    b.neighbors.iter().map(|nb| (nb.key, nb.distance.to_bits())).collect();
                assert_eq!(abits, bbits, "probe {probe}: row storage must not move a bit");
            }
        }
    }

    #[test]
    fn neighbor_order_is_total_under_distance_ties() {
        // Two identical rows tie at any distance; key order breaks it.
        let row = vec![1.0f32; 4];
        let entries = vec![(key(2), row.clone()), (key(1), row.clone())];
        let idx = AnnIndex::build(entries, 4, &AnnConfig::default());
        let q = idx.nearest(&row, 2, 1.0);
        assert_eq!(q.neighbors[0].key, key(1).min(key(2)));
        assert_eq!(q.neighbors[0].distance.to_bits(), q.neighbors[1].distance.to_bits());
    }
}
