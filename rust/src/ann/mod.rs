//! ann: approximate nearest-neighbor retrieval (IVFFlat) over the
//! persistent embedding store — the `nearest` serve op's engine.
//!
//! Dataflow (zero-copy since the mmap refactor):
//!
//! ```text
//!   EmbeddingStore (live rows; sealed segments mmap'd)
//!        | snapshot_row_data()      &self under a brief store lock:
//!        |                          RowData::View per sealed row
//!        v                          (no copy), RowData::Owned only
//!        |                          for the active-segment tail
//!        v
//!   seeded Lloyd's k-means         kmeans::lloyd_rows, runs OFF the
//!        |                         lock, reads rows in place
//!        | nlist = min(isqrt(n), centroid_cap) centroids
//!        v
//!   AnnIndex: centroids + per-centroid posting lists of row ids;
//!   rows[i] is a view into the page cache (indexed_bytes ≈ 0)
//!        |
//!        |   query row (embedded by the pipeline)
//!        |        |
//!        |        +-- probe in (0,1): rank centroids, scan the
//!        |        |   ceil(probe * nlist) nearest lists
//!        |        +-- probe >= 1.0 OR n < min_brute: exhaustive
//!        |            scan of every row (the exact oracle)
//!        v        v
//!   candidates --> exact L2 (l2_distance: f64 accumulate -> f32)
//!        v
//!   sort by (distance, key) -> top-k Neighbors
//! ```
//!
//! Generation lifecycle: every view holds an `Arc` to its segment's
//! mapping, so a built index is self-contained — when compaction
//! rewrites the store into a new generation and unlinks the old files,
//! the *current* index keeps serving bitwise-correct rows out of the
//! old (still-mapped) pages, and the single-flight rebuild then swaps
//! in an index over the new generation atomically (one `Arc` store
//! under `AnnCell`'s lock). Readers never observe a mix: a query runs
//! entirely against whichever index generation it grabbed.
//!
//! The serve cache layers a **pending tail** on top: rows persisted
//! after the last build are brute-scanned alongside the index until a
//! background rebuild absorbs them, so `index ∪ pending` always covers
//! every live row and probe 1.0 stays exact-complete at any moment.
//! Distances are exact on every path (the "approximate" part is only
//! *which rows are considered* at probe < 1.0); ids and distances at
//! probe 1.0 are pinned bitwise to a brute-force oracle by
//! `tests/ann.rs`, and view-backed vs copy-backed builds are pinned
//! bitwise-identical by `tests/mmap.rs`.

mod ivf;
mod kmeans;

pub use ivf::{
    l2_distance, neighbor_cmp, AnnConfig, AnnIndex, AnnQuery, Neighbor, DEFAULT_CENTROID_CAP,
    DEFAULT_KMEANS_ITERS, DEFAULT_MIN_BRUTE, DEFAULT_PROBE, DEFAULT_REBUILD_PENDING,
};
pub use kmeans::{lloyd, lloyd_rows, Kmeans};
