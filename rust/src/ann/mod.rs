//! ann: approximate nearest-neighbor retrieval (IVFFlat) over the
//! persistent embedding store — the `nearest` serve op's engine.
//!
//! Dataflow:
//!
//! ```text
//!   EmbeddingStore (live rows)
//!        | snapshot_rows()          brief store lock, key-sorted
//!        v
//!   seeded Lloyd's k-means         kmeans::lloyd, runs OFF the lock
//!        | nlist = min(isqrt(n), centroid_cap) centroids
//!        v
//!   AnnIndex: centroids + per-centroid posting lists of row ids
//!        |
//!        |   query row (embedded by the pipeline)
//!        |        |
//!        |        +-- probe in (0,1): rank centroids, scan the
//!        |        |   ceil(probe * nlist) nearest lists
//!        |        +-- probe >= 1.0 OR n < min_brute: exhaustive
//!        |            scan of every row (the exact oracle)
//!        v        v
//!   candidates --> exact L2 (l2_distance: f64 accumulate -> f32)
//!        v
//!   sort by (distance, key) -> top-k Neighbors
//! ```
//!
//! The serve cache layers a **pending tail** on top: rows persisted
//! after the last build are brute-scanned alongside the index until a
//! background rebuild absorbs them, so `index ∪ pending` always covers
//! every live row and probe 1.0 stays exact-complete at any moment.
//! Distances are exact on every path (the "approximate" part is only
//! *which rows are considered* at probe < 1.0); ids and distances at
//! probe 1.0 are pinned bitwise to a brute-force oracle by
//! `tests/ann.rs`.

mod ivf;
mod kmeans;

pub use ivf::{
    l2_distance, neighbor_cmp, AnnConfig, AnnIndex, AnnQuery, Neighbor, DEFAULT_CENTROID_CAP,
    DEFAULT_KMEANS_ITERS, DEFAULT_MIN_BRUTE, DEFAULT_PROBE, DEFAULT_REBUILD_PENDING,
};
pub use kmeans::{lloyd, Kmeans};
