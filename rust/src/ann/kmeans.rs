//! Seeded Lloyd's k-means over `f32` rows — flat row-major slices
//! ([`lloyd`]) or any indexed row storage ([`lloyd_rows`], which the
//! IVF builder feeds zero-copy mmap views).
//!
//! This is the clustering stage of the IVFFlat index: deliberately
//! small, dependency-free, and **deterministic** — same rows, same
//! seed, same iteration budget ⇒ bitwise-identical centroids on every
//! platform. Determinism is load-bearing: the differential battery in
//! `tests/ann.rs` compares an index built from an in-memory corpus
//! against one built from a store snapshot, and the daemon restart
//! test asserts a rebuilt index serves identical neighbors.
//!
//! Design points:
//! - **Init**: `k` distinct rows chosen by [`crate::util::Rng::sample_distinct`]
//!   and sorted, so the initial centroid order is a pure function of
//!   (rows, seed) — independent of Floyd-sampling order.
//! - **Assignment**: strict `<` comparison over f64-accumulated squared
//!   distances; ties go to the lowest centroid index.
//! - **Update**: f64 sums divided by counts, rounded once to f32 —
//!   summation order is fixed (row order), so means are reproducible.
//! - **Empty clusters**: reseeded each step from the farthest unclaimed
//!   point (distance to its own fresh centroid; ties to the lowest row
//!   index). A reseed copies a real row, so centroids can never be NaN
//!   even on adversarial all-identical input.
//! - **Termination**: stable assignment or `max_iters`, whichever comes
//!   first. `max_iters` is clamped to ≥ 1 so `assign` is always
//!   populated.

use crate::util::Rng;

/// Result of a Lloyd's run: `centroids` is `k × dim` row-major,
/// `assign[i]` is the centroid index of row `i`.
#[derive(Clone, Debug)]
pub struct Kmeans {
    pub centroids: Vec<f32>,
    pub assign: Vec<u32>,
    pub k: usize,
    pub iters: usize,
}

/// Squared L2 between two rows, accumulated in f64. Shared by the
/// assignment and reseed steps so "nearest centroid" means the same
/// thing everywhere inside one run.
#[inline]
fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = f64::from(x) - f64::from(y);
        acc += d * d;
    }
    acc
}

/// Run seeded Lloyd's k-means on `rows` (`n × dim`, row-major).
///
/// Contract: `dim > 0`, `rows.len()` is a multiple of `dim`, and
/// `1 <= k <= n`. Callers (the IVF builder) clamp `k` before calling.
pub fn lloyd(rows: &[f32], dim: usize, k: usize, seed: u64, max_iters: usize) -> Kmeans {
    assert!(dim > 0, "kmeans: dim must be positive");
    assert_eq!(rows.len() % dim, 0, "kmeans: rows not a multiple of dim");
    lloyd_rows(rows.len() / dim, dim, |i| &rows[i * dim..(i + 1) * dim], k, seed, max_iters)
}

/// The generic core of [`lloyd`]: rows are reached through an accessor
/// (`row(i)` → the i-th row, length `dim`) instead of one flat slice,
/// so the IVF builder can cluster zero-copy [`crate::store::RowData`]
/// views without first flattening them into an owned buffer. Iteration
/// order, accumulation order, and every comparison are identical to the
/// flat-slice path — `lloyd` delegates here — so results stay bitwise
/// reproducible regardless of how rows are stored.
///
/// Contract: `dim > 0`, `1 <= k <= n`, and every `row(i)` for
/// `i < n` has length `dim`.
pub fn lloyd_rows<'a>(
    n: usize,
    dim: usize,
    row: impl Fn(usize) -> &'a [f32],
    k: usize,
    seed: u64,
    max_iters: usize,
) -> Kmeans {
    assert!(dim > 0, "kmeans: dim must be positive");
    assert!(k >= 1 && k <= n, "kmeans: need 1 <= k={k} <= n={n}");

    // Deterministic init: k distinct row indices, sorted so centroid
    // order does not depend on Floyd's sampling order.
    let mut picks = Vec::new();
    Rng::new(seed).sample_distinct(n, k, &mut picks);
    picks.sort_unstable();
    let mut centroids = Vec::with_capacity(k * dim);
    for &i in &picks {
        let r = row(i);
        debug_assert_eq!(r.len(), dim, "kmeans: row {i} has the wrong length");
        centroids.extend_from_slice(r);
    }

    let mut assign = vec![0u32; n];
    let mut iters = 0usize;
    for _ in 0..max_iters.max(1) {
        iters += 1;

        // Assignment: nearest centroid, strict `<` so ties resolve to
        // the lowest centroid index.
        let mut changed = false;
        for (i, a) in assign.iter_mut().enumerate() {
            let r = row(i);
            let mut best = 0u32;
            let mut best_d = f64::INFINITY;
            for (c, cent) in centroids.chunks_exact(dim).enumerate() {
                let d = dist_sq(r, cent);
                if d < best_d {
                    best_d = d;
                    best = c as u32;
                }
            }
            if *a != best {
                *a = best;
                changed = true;
            }
        }
        // Converged: assignments are stable under the current centroids
        // (after iteration 1, which must run the update at least once).
        if iters > 1 && !changed {
            break;
        }

        // Update: f64 accumulators in fixed row order.
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0u32; k];
        for (i, &a) in assign.iter().enumerate() {
            let a = a as usize;
            counts[a] += 1;
            for (s, &x) in sums[a * dim..(a + 1) * dim].iter_mut().zip(row(i)) {
                *s += f64::from(x);
            }
        }
        for ((sum, cent), &count) in sums
            .chunks_exact(dim)
            .zip(centroids.chunks_exact_mut(dim))
            .zip(counts.iter())
        {
            if count > 0 {
                for (c, &s) in cent.iter_mut().zip(sum) {
                    *c = (s / f64::from(count)) as f32;
                }
            }
            // count == 0: keep the stale centroid; the reseed below
            // overwrites it with a real row.
        }

        // Deterministic empty-cluster reseeding: each empty cluster (in
        // ascending index) takes the unclaimed row farthest from its
        // own fresh centroid (ties → lowest row index).
        if counts.iter().any(|&c| c == 0) {
            let mut claimed = vec![false; n];
            for c in 0..k {
                if counts[c] > 0 {
                    continue;
                }
                let mut best: Option<(f64, usize)> = None;
                for i in 0..n {
                    if claimed[i] {
                        continue;
                    }
                    let a = assign[i] as usize;
                    let d = dist_sq(row(i), &centroids[a * dim..(a + 1) * dim]);
                    let farther = match best {
                        None => true,
                        Some((bd, _)) => d > bd,
                    };
                    if farther {
                        best = Some((d, i));
                    }
                }
                if let Some((_, i)) = best {
                    claimed[i] = true;
                    centroids[c * dim..(c + 1) * dim].copy_from_slice(row(i));
                }
            }
            // A reseed moved a centroid: the next assignment pass must
            // run (it either changes something or proves stability).
        }
    }

    Kmeans { centroids, assign, k, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_rows(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rows = vec![0.0f32; n * dim];
        Rng::new(seed).fill_gaussian(&mut rows, 1.0);
        rows
    }

    #[test]
    fn same_rows_and_seed_give_bitwise_identical_centroids() {
        let rows = gaussian_rows(80, 16, 0x5EED);
        let a = lloyd(&rows, 16, 9, 7, 12);
        let b = lloyd(&rows, 16, 9, 7, 12);
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.assign, b.assign);
        let abits: Vec<u32> = a.centroids.iter().map(|x| x.to_bits()).collect();
        let bbits: Vec<u32> = b.centroids.iter().map(|x| x.to_bits()).collect();
        assert_eq!(abits, bbits, "centroids must be bitwise reproducible");
    }

    #[test]
    fn empty_cluster_reseeding_terminates_within_the_iteration_budget() {
        // 32 identical rows with k=8: init picks 8 identical centroids,
        // every row ties to centroid 0, clusters 1..8 go empty and must
        // be reseeded each step — the run still has to terminate.
        let dim = 8;
        let row: Vec<f32> = (0..dim).map(|j| 1.5 + j as f32).collect();
        let mut rows = Vec::new();
        for _ in 0..32 {
            rows.extend_from_slice(&row);
        }
        let km = lloyd(&rows, dim, 8, 3, 10);
        assert!(km.iters <= 10);
        assert_eq!(km.assign.len(), 32);
        assert!(km.assign.iter().all(|&a| (a as usize) < 8));
        assert!(km.centroids.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn all_identical_rows_yield_nan_free_centroids_equal_to_the_row() {
        let dim = 4;
        let row = [0.25f32, -3.0, 7.5, 0.0];
        let mut rows = Vec::new();
        for _ in 0..10 {
            rows.extend_from_slice(&row);
        }
        let km = lloyd(&rows, dim, 3, 99, 12);
        assert!(km.centroids.iter().all(|x| x.is_finite()), "NaN centroid on identical input");
        // Means of identical rows and reseeds of identical rows are
        // both the row itself.
        for cent in km.centroids.chunks_exact(dim) {
            assert_eq!(cent, &row[..]);
        }
    }

    #[test]
    fn k_equals_n_assigns_each_row_its_own_centroid() {
        let rows = gaussian_rows(6, 5, 42);
        let km = lloyd(&rows, 5, 6, 1, 12);
        let mut seen = km.assign.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 6, "distinct rows with k=n must occupy distinct clusters");
    }

    #[test]
    fn k_equals_one_centroid_is_the_global_mean() {
        let dim = 3;
        let rows = [0.0f32, 0.0, 0.0, 2.0, 4.0, 6.0];
        let km = lloyd(&rows, dim, 1, 5, 12);
        assert_eq!(km.centroids.len(), dim);
        assert_eq!(km.centroids, vec![1.0, 2.0, 3.0]);
        assert_eq!(km.assign, vec![0, 0]);
    }

    #[test]
    fn lloyd_rows_over_scattered_storage_is_bitwise_lloyd() {
        // The accessor-generic core must not depend on rows being one
        // contiguous buffer: hand it individually-boxed rows and demand
        // bitwise-identical centroids and assignments.
        let dim = 16;
        let flat = gaussian_rows(70, dim, 0xBEE5);
        let scattered: Vec<Vec<f32>> =
            flat.chunks_exact(dim).map(|r| r.to_vec()).collect();
        let a = lloyd(&flat, dim, 8, 11, 12);
        let b = lloyd_rows(70, dim, |i| scattered[i].as_slice(), 8, 11, 12);
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.assign, b.assign);
        let abits: Vec<u32> = a.centroids.iter().map(|x| x.to_bits()).collect();
        let bbits: Vec<u32> = b.centroids.iter().map(|x| x.to_bits()).collect();
        assert_eq!(abits, bbits, "row storage must be invisible to the math");
    }

    #[test]
    fn max_iters_zero_is_clamped_and_still_assigns() {
        let rows = gaussian_rows(12, 4, 8);
        let km = lloyd(&rows, 4, 3, 2, 0);
        assert_eq!(km.iters, 1);
        assert_eq!(km.assign.len(), 12);
    }
}
