//! # graphlet-rf — Fast Graph Kernel with Optical Random Features
//!
//! A three-layer reproduction of Ghanem, Keriven & Tremblay (2020):
//! graph classification by **G**raphlet **S**ampling and **A**veraging
//! with random feature maps (GSA-phi), including a simulated optical
//! processing unit (OPU) feature map executed through AOT-compiled XLA
//! artifacts.
//!
//! Layering (DESIGN.md §3):
//! - **L3 (this crate)**: datasets, samplers, the exact graphlet-kernel
//!   baseline, the sharded batching pipeline, classifier, benches and
//!   the CLI.
//! - **L2/L1 (python, build-time only)**: jax feature models and Pallas
//!   kernels lowered to `artifacts/*.hlo.txt` by `make artifacts`.
//! - **runtime**: loads those artifacts over PJRT (`xla` crate) and
//!   executes them from the request path — python is never loaded at
//!   runtime. (The offline build vendors an `xla` stub; the runtime then
//!   reports PJRT as unavailable and everything falls back to the CPU
//!   feature engines.)
//!
//! The embedding hot path is a **persistent sharded dataflow**
//! ([`coordinator::StreamingPipeline`]): W sampler workers feed N
//! feature-engine shards over bounded per-shard channels; jobs are
//! round-robined over shards and rows from concurrent jobs pack into
//! cross-request batches of the compiled batch size. Each shard owns
//! its own executor (PJRT engine or CPU map clone) and per-job
//! accumulators, so the produced embeddings are bitwise identical for
//! every (W, N) and for every batching schedule — see [`coordinator`]
//! for the stage diagrams and invariants. One-shot experiments use the
//! [`coordinator::embed_dataset`] batch adapter; heavy traffic uses the
//! [`serve`] daemon (`graphlet-rf serve`), which keeps the pipeline and
//! artifacts warm across requests, batches rows from concurrent TCP
//! clients together, and fronts it all with a **two-level**
//! content-addressed embedding cache: an in-RAM LRU (optionally
//! cost-aware) over the crash-tolerant on-disk segment log in
//! [`store`] (`--store-dir`), so a daemon restart serves previously
//! computed rows bitwise identical from disk instead of recomputing
//! them. On top of the store, [`ann`] builds an IVFFlat index (seeded
//! k-means centroids + inverted posting lists) so the daemon's
//! `nearest` op answers "which known graphs is this most similar to?"
//! — k-NN retrieval over every stored embedding with exact L2
//! distances, probe-factor tunable, pinned to a brute-force oracle.
//!
//! Three CPU feature engines back the shards when PJRT is unavailable
//! (and serve as baselines when it is): the dense maps in [`features`]
//! (`--engine cpu` / `cpu-inline`) and the **structured** SORF map in
//! [`fastrf`] (`--engine cpu-sorf`), which replaces the dense `O(d·m)`
//! projection with `HD`-product blocks computed by a **batch-major**
//! fast Walsh–Hadamard transform in `O(p log p)` — the software
//! analogue of the paper's constant-time optical transform. Each shard
//! executes its batches panel-wise (one diagonal pass + one batched
//! FWHT per round over the whole batch) and can split independent
//! blocks or panel rows across a `--fwht-threads` budget, with
//! embeddings bitwise identical at every setting. See [`fastrf`] for
//! the dataflow diagram and calibration.
//!
//! Where the time goes is first-class: [`obs`] is a zero-dependency
//! observability layer — instance-scoped registries of atomic counters,
//! gauges, and log₂-bucketed latency histograms (each serve daemon owns
//! one; the batch CLI uses a process-wide default), plus per-request
//! span tracing that stamps every stage a request crosses (admission,
//! queue wait, projection, cache probe, L2 read, ANN search, reply
//! write) and keeps recent spans in a ring served by the daemon's
//! `metrics` and `trace` ops. With `--http-port` the daemon also serves
//! its registry in Prometheus text format on `/metrics` (plus
//! `/healthz` and `/readyz`). Spans slower than `--slow-ms` log one
//! structured JSON line to stderr. Tracing is pure observation:
//! embeddings are bitwise identical with it on or off.
//!
//! Quick tour: generate a dataset ([`gen`]), sample graphlets
//! ([`sample`]), embed them with a feature map ([`features`] on CPU,
//! [`fastrf`] for structured features, or [`runtime`] +
//! [`coordinator`] for the batched, sharded PJRT pipeline), train the
//! linear tail ([`classify`]), reproduce a paper figure
//! ([`experiments`]), or run the embedding service ([`serve`]).

pub mod ann;
pub mod classify;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fastrf;
pub mod features;
pub mod gen;
pub mod gnn;
pub mod graph;
pub mod iso;
pub mod kernelgk;
pub mod mmd;
pub mod obs;
pub mod runtime;
pub mod sample;
pub mod serve;
pub mod store;
pub mod util;
