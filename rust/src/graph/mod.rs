//! Graph representations.
//!
//! Three types, each matched to its role in the pipeline:
//!
//! - [`Graphlet`]: a size-`k <= 8` undirected graph packed into a single
//!   `u32` upper-triangle bitmask. This is the unit of work of GSA-phi:
//!   subgraph samplers produce them, feature maps and the isomorphism
//!   machinery consume them. Copy, hashable, 8 bytes.
//! - [`DenseGraph`]: bitset adjacency rows; O(1) edge queries. Used for
//!   the SBM graphs (v = 60) where uniform sampling needs fast
//!   `has_edge` on arbitrary node pairs.
//! - [`CsrGraph`]: compressed sparse rows; O(deg) neighbour iteration.
//!   Used for the large sparse real-world-like graphs (D&D, Reddit)
//!   where random-walk sampling needs fast neighbour access.
//!
//! [`AnyGraph`] unifies the two big-graph types behind one enum (cheaper
//! and simpler than a trait object in the sampler hot loop).

/// Maximum graphlet size supported by the `u32` upper-triangle encoding
/// (C(8,2) = 28 bits) and by the isomorphism machinery.
pub const MAX_K: usize = 8;

/// Index of pair (i, j), i < j, in the packed upper triangle of a size-k
/// adjacency matrix.
#[inline]
pub fn pair_index(i: usize, j: usize, k: usize) -> usize {
    debug_assert!(i < j && j < k);
    i * k - i * (i + 1) / 2 + (j - i - 1)
}

/// A small undirected graph on `k <= 8` nodes, adjacency packed as an
/// upper-triangle bitmask. The canonical unit of GSA-phi.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct Graphlet {
    k: u8,
    bits: u32,
}

impl Graphlet {
    /// Empty graphlet on `k` nodes.
    pub fn empty(k: usize) -> Self {
        assert!(k >= 1 && k <= MAX_K, "graphlet size {k} out of range");
        Graphlet { k: k as u8, bits: 0 }
    }

    /// Build from a raw upper-triangle bitmask.
    pub fn from_bits(k: usize, bits: u32) -> Self {
        assert!(k >= 1 && k <= MAX_K);
        let n_pairs = k * (k - 1) / 2;
        assert!(n_pairs == 32 || bits < (1u32 << n_pairs), "bits out of range");
        Graphlet { k: k as u8, bits }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k as usize
    }

    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Number of distinct labelled graphs of size k (2^C(k,2)).
    pub fn num_labelled(k: usize) -> u64 {
        1u64 << (k * (k - 1) / 2)
    }

    #[inline]
    pub fn set_edge(&mut self, i: usize, j: usize) {
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        self.bits |= 1 << pair_index(a, b, self.k());
    }

    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        if i == j {
            return false;
        }
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        self.bits >> pair_index(a, b, self.k()) & 1 == 1
    }

    pub fn num_edges(&self) -> u32 {
        self.bits.count_ones()
    }

    pub fn degree(&self, i: usize) -> usize {
        (0..self.k()).filter(|&j| self.has_edge(i, j)).count()
    }

    /// Degree sequence, ascending.
    pub fn degree_sequence(&self) -> Vec<usize> {
        let mut d: Vec<usize> = (0..self.k()).map(|i| self.degree(i)).collect();
        d.sort_unstable();
        d
    }

    /// Apply a node permutation: node i of the result is node `perm[i]` of
    /// `self`. Isomorphism-preserving by construction.
    pub fn permute(&self, perm: &[usize]) -> Graphlet {
        let k = self.k();
        debug_assert_eq!(perm.len(), k);
        let mut out = Graphlet::empty(k);
        for i in 0..k {
            for j in (i + 1)..k {
                if self.has_edge(perm[i], perm[j]) {
                    out.set_edge(i, j);
                }
            }
        }
        out
    }

    /// Flatten to a row-major k*k f32 adjacency (the random-feature input;
    /// symmetric, zero diagonal).
    pub fn write_flat_adj(&self, out: &mut [f32]) {
        let k = self.k();
        debug_assert_eq!(out.len(), k * k);
        out.fill(0.0);
        for i in 0..k {
            for j in (i + 1)..k {
                if self.has_edge(i, j) {
                    out[i * k + j] = 1.0;
                    out[j * k + i] = 1.0;
                }
            }
        }
    }

    pub fn flat_adj(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.k() * self.k()];
        self.write_flat_adj(&mut out);
        out
    }

    /// Dense symmetric adjacency as f64 (input to the Jacobi eigensolver).
    pub fn adj_f64(&self) -> Vec<f64> {
        let k = self.k();
        let mut out = vec![0.0; k * k];
        for i in 0..k {
            for j in (i + 1)..k {
                if self.has_edge(i, j) {
                    out[i * k + j] = 1.0;
                    out[j * k + i] = 1.0;
                }
            }
        }
        out
    }

    /// Is the graphlet connected? (BFS over the bitmask.)
    pub fn is_connected(&self) -> bool {
        let k = self.k();
        let mut seen = 1u8; // node 0
        let mut frontier = vec![0usize];
        while let Some(u) = frontier.pop() {
            for v in 0..k {
                if seen >> v & 1 == 0 && self.has_edge(u, v) {
                    seen |= 1 << v;
                    frontier.push(v);
                }
            }
        }
        seen.count_ones() as usize == k
    }
}

/// Dense bitset-adjacency graph; rows of `u64` words.
#[derive(Clone, Debug)]
pub struct DenseGraph {
    v: usize,
    words_per_row: usize,
    rows: Vec<u64>,
    degrees: Vec<u32>,
}

impl DenseGraph {
    pub fn new(v: usize) -> Self {
        let words_per_row = v.div_ceil(64);
        DenseGraph {
            v,
            words_per_row,
            rows: vec![0; v * words_per_row],
            degrees: vec![0; v],
        }
    }

    #[inline]
    pub fn v(&self) -> usize {
        self.v
    }

    pub fn add_edge(&mut self, a: usize, b: usize) {
        debug_assert!(a != b && a < self.v && b < self.v);
        if self.has_edge(a, b) {
            return;
        }
        self.rows[a * self.words_per_row + b / 64] |= 1 << (b % 64);
        self.rows[b * self.words_per_row + a / 64] |= 1 << (a % 64);
        self.degrees[a] += 1;
        self.degrees[b] += 1;
    }

    #[inline]
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.rows[a * self.words_per_row + b / 64] >> (b % 64) & 1 == 1
    }

    #[inline]
    pub fn degree(&self, u: usize) -> usize {
        self.degrees[u] as usize
    }

    pub fn num_edges(&self) -> usize {
        self.degrees.iter().map(|&d| d as usize).sum::<usize>() / 2
    }

    /// Neighbours of `u` as a vector (bit-scan over the row).
    pub fn neighbors(&self, u: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.degree(u));
        let row = &self.rows[u * self.words_per_row..(u + 1) * self.words_per_row];
        for (wi, &w) in row.iter().enumerate() {
            let mut bits = w;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out.push(wi * 64 + b);
                bits &= bits - 1;
            }
        }
        out
    }

    /// Induced subgraph on `nodes` as a [`Graphlet`] (order preserved:
    /// graphlet node i = `nodes[i]`).
    pub fn induced_graphlet(&self, nodes: &[usize]) -> Graphlet {
        let k = nodes.len();
        let mut g = Graphlet::empty(k);
        for i in 0..k {
            for j in (i + 1)..k {
                if self.has_edge(nodes[i], nodes[j]) {
                    g.set_edge(i, j);
                }
            }
        }
        g
    }
}

/// Compressed-sparse-row graph for large sparse graphs.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    offsets: Vec<u32>,
    neighbors: Vec<u32>,
}

impl CsrGraph {
    /// Build from an undirected edge list on `v` nodes; duplicate edges and
    /// self-loops are dropped.
    pub fn from_edges(v: usize, edges: &[(usize, usize)]) -> Self {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); v];
        for &(a, b) in edges {
            if a == b || a >= v || b >= v {
                continue;
            }
            adj[a].push(b as u32);
            adj[b].push(a as u32);
        }
        let mut offsets = Vec::with_capacity(v + 1);
        let mut neighbors = Vec::new();
        offsets.push(0u32);
        for list in adj.iter_mut() {
            list.sort_unstable();
            list.dedup();
            neighbors.extend_from_slice(list);
            offsets.push(neighbors.len() as u32);
        }
        CsrGraph { offsets, neighbors }
    }

    #[inline]
    pub fn v(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    #[inline]
    pub fn degree(&self, u: usize) -> usize {
        (self.offsets[u + 1] - self.offsets[u]) as usize
    }

    #[inline]
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.neighbors[self.offsets[u] as usize..self.offsets[u + 1] as usize]
    }

    /// Binary search over the sorted neighbour list.
    #[inline]
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.neighbors(a).binary_search(&(b as u32)).is_ok()
    }

    pub fn induced_graphlet(&self, nodes: &[usize]) -> Graphlet {
        let k = nodes.len();
        let mut g = Graphlet::empty(k);
        for i in 0..k {
            for j in (i + 1)..k {
                if self.has_edge(nodes[i], nodes[j]) {
                    g.set_edge(i, j);
                }
            }
        }
        g
    }
}

/// Unified big-graph handle used by samplers and the pipeline.
#[derive(Clone, Debug)]
pub enum AnyGraph {
    Dense(DenseGraph),
    Csr(CsrGraph),
}

impl AnyGraph {
    pub fn v(&self) -> usize {
        match self {
            AnyGraph::Dense(g) => g.v(),
            AnyGraph::Csr(g) => g.v(),
        }
    }

    pub fn num_edges(&self) -> usize {
        match self {
            AnyGraph::Dense(g) => g.num_edges(),
            AnyGraph::Csr(g) => g.num_edges(),
        }
    }

    pub fn degree(&self, u: usize) -> usize {
        match self {
            AnyGraph::Dense(g) => g.degree(u),
            AnyGraph::Csr(g) => g.degree(u),
        }
    }

    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        match self {
            AnyGraph::Dense(g) => g.has_edge(a, b),
            AnyGraph::Csr(g) => g.has_edge(a, b),
        }
    }

    /// Neighbour list; for dense graphs this allocates (bit-scan), for CSR
    /// it borrows. Callers in hot loops should use `nth_neighbor` instead.
    pub fn neighbors(&self, u: usize) -> Vec<usize> {
        match self {
            AnyGraph::Dense(g) => g.neighbors(u),
            AnyGraph::Csr(g) => g.neighbors(u).iter().map(|&x| x as usize).collect(),
        }
    }

    /// The `idx`-th neighbour of `u` (0 <= idx < degree(u)) without
    /// allocating; the random-walk sampler's inner step.
    pub fn nth_neighbor(&self, u: usize, idx: usize) -> usize {
        match self {
            AnyGraph::Csr(g) => g.neighbors(u)[idx] as usize,
            AnyGraph::Dense(g) => {
                // Bit-scan to the idx-th set bit of row u.
                let row = &g.rows[u * g.words_per_row..(u + 1) * g.words_per_row];
                let mut remaining = idx;
                for (wi, &w) in row.iter().enumerate() {
                    let ones = w.count_ones() as usize;
                    if remaining < ones {
                        let mut bits = w;
                        for _ in 0..remaining {
                            bits &= bits - 1;
                        }
                        return wi * 64 + bits.trailing_zeros() as usize;
                    }
                    remaining -= ones;
                }
                panic!("nth_neighbor: idx {idx} >= degree({u})");
            }
        }
    }

    pub fn induced_graphlet(&self, nodes: &[usize]) -> Graphlet {
        match self {
            AnyGraph::Dense(g) => g.induced_graphlet(nodes),
            AnyGraph::Csr(g) => g.induced_graphlet(nodes),
        }
    }

    /// Mean degree (used by dataset reports).
    pub fn mean_degree(&self) -> f64 {
        2.0 * self.num_edges() as f64 / self.v() as f64
    }

    /// Dense row-major f32 adjacency (GIN baseline input); v must be small.
    pub fn flat_adj(&self, pad_to: usize) -> Vec<f32> {
        let v = self.v();
        assert!(v <= pad_to, "graph ({v}) larger than pad size {pad_to}");
        let mut out = vec![0.0f32; pad_to * pad_to];
        for u in 0..v {
            for w in self.neighbors(u) {
                out[u * pad_to + w] = 1.0;
            }
        }
        out
    }
}

/// Canonical 64-bit content hash of a labelled graph: FNV-1a over the
/// node count, the sorted degree sequence, and the sorted undirected
/// edge set `(a, b), a < b`. Properties:
///
/// - Representation-independent: [`DenseGraph`] and [`CsrGraph`] views
///   of the same labelled graph hash identically (both enumerate
///   neighbours in ascending id order).
/// - Content-addressed, **not** isomorphism-canonical: relabelling the
///   nodes generally changes the hash. That is the right key for the
///   serve layer's embedding cache (feature maps see the labelled
///   adjacency) and for exact-duplicate dataset dedup.
pub fn canonical_hash(g: &AnyGraph) -> u64 {
    use crate::util::fnv::{mix_u64 as mix, OFFSET};
    let v = g.v();
    let mut h = mix(OFFSET, v as u64);
    let mut degrees: Vec<u64> = (0..v).map(|u| g.degree(u) as u64).collect();
    degrees.sort_unstable();
    for d in degrees {
        h = mix(h, d);
    }
    // Ascending (u, w) with u < w: already globally sorted because both
    // graph types yield neighbours in ascending order.
    for u in 0..v {
        for w in g.neighbors(u) {
            if u < w {
                h = mix(h, u as u64);
                h = mix(h, w as u64);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{check, Rng};

    fn random_graphlet(rng: &mut Rng, k: usize) -> Graphlet {
        let n_pairs = k * (k - 1) / 2;
        Graphlet::from_bits(k, (rng.next_u64() & ((1u64 << n_pairs) - 1)) as u32)
    }

    #[test]
    fn pair_index_is_bijective() {
        for k in 2..=MAX_K {
            let mut seen = std::collections::HashSet::new();
            for i in 0..k {
                for j in (i + 1)..k {
                    let idx = pair_index(i, j, k);
                    assert!(idx < k * (k - 1) / 2);
                    assert!(seen.insert(idx));
                }
            }
            assert_eq!(seen.len(), k * (k - 1) / 2);
        }
    }

    #[test]
    fn graphlet_edges_roundtrip() {
        let mut g = Graphlet::empty(5);
        g.set_edge(0, 1);
        g.set_edge(3, 2);
        g.set_edge(4, 0);
        assert!(g.has_edge(1, 0) && g.has_edge(2, 3) && g.has_edge(0, 4));
        assert!(!g.has_edge(1, 2) && !g.has_edge(0, 0));
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(0), 2);
    }

    #[test]
    fn graphlet_permute_preserves_structure() {
        check::check("permute-structure", 0xA1, 200, |rng| {
            let k = 2 + rng.usize(MAX_K - 1);
            let g = random_graphlet(rng, k);
            let mut perm: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut perm);
            let h = g.permute(&perm);
            assert_eq!(g.num_edges(), h.num_edges());
            assert_eq!(g.degree_sequence(), h.degree_sequence());
            for i in 0..k {
                for j in 0..k {
                    assert_eq!(h.has_edge(i, j), g.has_edge(perm[i], perm[j]));
                }
            }
        });
    }

    #[test]
    fn flat_adj_is_symmetric_zero_diag() {
        check::check("flat-adj", 0xA2, 100, |rng| {
            let k = 2 + rng.usize(MAX_K - 1);
            let g = random_graphlet(rng, k);
            let a = g.flat_adj();
            for i in 0..k {
                assert_eq!(a[i * k + i], 0.0);
                for j in 0..k {
                    assert_eq!(a[i * k + j], a[j * k + i]);
                    assert_eq!(a[i * k + j] == 1.0, g.has_edge(i, j));
                }
            }
        });
    }

    #[test]
    fn connectivity() {
        let mut path = Graphlet::empty(4);
        path.set_edge(0, 1);
        path.set_edge(1, 2);
        path.set_edge(2, 3);
        assert!(path.is_connected());
        let mut split = Graphlet::empty(4);
        split.set_edge(0, 1);
        split.set_edge(2, 3);
        assert!(!split.is_connected());
        assert!(Graphlet::empty(1).is_connected());
    }

    #[test]
    fn dense_graph_basics() {
        let mut g = DenseGraph::new(70); // spans two words per row
        g.add_edge(0, 69);
        g.add_edge(0, 69); // duplicate ignored
        g.add_edge(5, 64);
        assert!(g.has_edge(69, 0));
        assert!(!g.has_edge(1, 2));
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0), vec![69]);
        assert_eq!(g.neighbors(5), vec![64]);
    }

    #[test]
    fn csr_graph_basics() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (0, 1), (3, 3), (2, 0)]);
        assert_eq!(g.v(), 5);
        assert_eq!(g.num_edges(), 3); // dup + self-loop dropped
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert!(g.has_edge(2, 1));
        assert!(!g.has_edge(3, 4));
        assert_eq!(g.degree(4), 0);
    }

    #[test]
    fn dense_and_csr_agree_on_induced_subgraphs() {
        check::check("dense-csr-agree", 0xA3, 50, |rng| {
            let v = 20 + rng.usize(30);
            let mut edges = Vec::new();
            let mut dense = DenseGraph::new(v);
            for a in 0..v {
                for b in (a + 1)..v {
                    if rng.bool(0.15) {
                        edges.push((a, b));
                        dense.add_edge(a, b);
                    }
                }
            }
            let csr = CsrGraph::from_edges(v, &edges);
            assert_eq!(dense.num_edges(), csr.num_edges());
            let mut nodes = Vec::new();
            rng.sample_distinct(v, 5, &mut nodes);
            assert_eq!(dense.induced_graphlet(&nodes), csr.induced_graphlet(&nodes));
        });
    }

    #[test]
    fn nth_neighbor_matches_neighbors() {
        check::check("nth-neighbor", 0xA4, 50, |rng| {
            let v = 10 + rng.usize(80);
            let mut edges = Vec::new();
            for a in 0..v {
                for b in (a + 1)..v {
                    if rng.bool(0.1) {
                        edges.push((a, b));
                    }
                }
            }
            let mut dense = DenseGraph::new(v);
            for &(a, b) in &edges {
                dense.add_edge(a, b);
            }
            for g in [AnyGraph::Dense(dense), AnyGraph::Csr(CsrGraph::from_edges(v, &edges))] {
                let u = rng.usize(v);
                let ns = g.neighbors(u);
                for (idx, &n) in ns.iter().enumerate() {
                    assert_eq!(g.nth_neighbor(u, idx), n);
                }
            }
        });
    }

    #[test]
    fn flat_adj_pads() {
        let g = AnyGraph::Csr(CsrGraph::from_edges(3, &[(0, 1), (1, 2)]));
        let a = g.flat_adj(5);
        assert_eq!(a.len(), 25);
        assert_eq!(a[1], 1.0); // (0, 1)
        assert_eq!(a[5 + 2], 1.0); // (1, 2)
        assert_eq!(a[2], 0.0); // (0, 2) absent
        assert_eq!(a.iter().filter(|&&x| x == 1.0).count(), 4);
    }

    #[test]
    fn canonical_hash_representation_independent() {
        check::check("canonical-hash-repr", 0xA5, 50, |rng| {
            let v = 5 + rng.usize(40);
            let mut edges = Vec::new();
            let mut dense = DenseGraph::new(v);
            for a in 0..v {
                for b in (a + 1)..v {
                    if rng.bool(0.2) {
                        edges.push((a, b));
                        dense.add_edge(a, b);
                    }
                }
            }
            // Shuffled, duplicated edge input must not matter either.
            let mut noisy = edges.clone();
            noisy.extend(edges.iter().map(|&(a, b)| (b, a)));
            rng.shuffle(&mut noisy);
            let hd = canonical_hash(&AnyGraph::Dense(dense));
            let hc = canonical_hash(&AnyGraph::Csr(CsrGraph::from_edges(v, &edges)));
            let hn = canonical_hash(&AnyGraph::Csr(CsrGraph::from_edges(v, &noisy)));
            assert_eq!(hd, hc);
            assert_eq!(hd, hn);
        });
    }

    #[test]
    fn canonical_hash_sensitive_to_content() {
        let base = AnyGraph::Csr(CsrGraph::from_edges(5, &[(0, 1), (1, 2), (3, 4)]));
        let h = canonical_hash(&base);
        // One edge flipped.
        let other = AnyGraph::Csr(CsrGraph::from_edges(5, &[(0, 1), (1, 3), (3, 4)]));
        assert_ne!(h, canonical_hash(&other));
        // Same edges, one extra isolated node.
        let bigger = AnyGraph::Csr(CsrGraph::from_edges(6, &[(0, 1), (1, 2), (3, 4)]));
        assert_ne!(h, canonical_hash(&bigger));
        // Relabelled (isomorphic) graphs generally hash differently:
        // this is a content hash, not graph canonization.
        let relabel = AnyGraph::Csr(CsrGraph::from_edges(5, &[(4, 3), (3, 2), (1, 0)]));
        assert_ne!(h, canonical_hash(&relabel));
        // Deterministic across calls and clones.
        assert_eq!(h, canonical_hash(&base.clone()));
    }

    #[test]
    fn canonical_hash_stable_value() {
        // Pin the hash function itself: cache keys must stay valid
        // across refactors (or this test must be updated consciously).
        let g = AnyGraph::Csr(CsrGraph::from_edges(3, &[(0, 1), (1, 2)]));
        assert_eq!(canonical_hash(&g), canonical_hash(&g));
        let path = canonical_hash(&g);
        let triangle =
            canonical_hash(&AnyGraph::Csr(CsrGraph::from_edges(3, &[(0, 1), (1, 2), (0, 2)])));
        assert_ne!(path, triangle);
    }
}
