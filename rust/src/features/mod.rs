//! CPU random-feature maps, the Jacobi eigensolver for the Gs+eig
//! variant, and the analytic OPU cost model.
//!
//! These serve three roles:
//! 1. **Fallback** feature engine when PJRT artifacts are unavailable
//!    (`--engine cpu`), with *identical math* to the L2 jax bodies —
//!    tests cross-check the two paths bit-for-bit-ish (allclose).
//! 2. **Baselines** for the Fig. 2 (right) / Table 1 timing study:
//!    `phi_Gs` and `phi_Gs+eig` per-subgraph cost measured here.
//! 3. **Parameter source**: the random matrices/biases generated here are
//!    the ones uploaded to the device for the PJRT path, so both engines
//!    share randomness given a seed.

pub mod eig;

use anyhow::{bail, Result};

use crate::graph::Graphlet;
use crate::util::Rng;

/// Variant tag used across config, runtime, and result files.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Simulated optical features `m^{-1/2} |Wx + b|^2` (phi_OPU).
    Opu,
    /// Gaussian features `sqrt(2/m) cos(Wx + b)` on flattened adjacency.
    Gauss,
    /// Gaussian features on sorted eigenvalues (phi_Gs+eig).
    GaussEig,
    /// Exact graphlet matching (phi_match) — the classical baseline.
    Match,
}

impl Variant {
    /// Parse a variant name; bad input is an `Err`, not a panic, so CLI
    /// callers can fail gracefully.
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "opu" => Variant::Opu,
            "gauss" | "gaussian" => Variant::Gauss,
            "gauss-eig" | "eig" => Variant::GaussEig,
            "match" => Variant::Match,
            other => bail!("unknown variant {other:?} (expected opu|gauss|gauss-eig|match)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Opu => "opu",
            Variant::Gauss => "gauss",
            Variant::GaussEig => "gauss-eig",
            Variant::Match => "match",
        }
    }

    /// Input dimension of the feature map for graphlet size k.
    pub fn input_dim(&self, k: usize) -> usize {
        match self {
            Variant::GaussEig => k,
            _ => k * k,
        }
    }

    /// Write the feature-map input for one graphlet into `out`.
    pub fn write_input(&self, g: &Graphlet, out: &mut [f32]) {
        match self {
            Variant::GaussEig => {
                let vals = eig::sorted_eigenvalues(&g.adj_f64(), g.k());
                for (o, v) in out.iter_mut().zip(vals) {
                    *o = v as f32;
                }
            }
            _ => g.write_flat_adj(out),
        }
    }
}

/// The random parameters of a feature map; uploaded to the device for the
/// PJRT engine or used directly by the CPU engine.
#[derive(Clone, Debug)]
pub struct RfParams {
    pub variant: Variant,
    pub d: usize,
    pub m: usize,
    /// gauss / gauss-eig: W (d*m) and b (m). opu: Wr, Wi (d*m), br, bi (m).
    pub mats: Vec<Vec<f32>>,
    pub biases: Vec<Vec<f32>>,
}

impl RfParams {
    /// Draw parameters. `sigma` scales the Gaussian frequency matrix
    /// (paper Fig. 2 uses sigma^2 = 0.01 for phi_Gs); the OPU transmission
    /// matrix is unit-variance complex Gaussian.
    pub fn generate(variant: Variant, d: usize, m: usize, sigma: f32, rng: &mut Rng) -> Self {
        let mat = |s: f32, rng: &mut Rng| {
            let mut w = vec![0.0f32; d * m];
            rng.fill_gaussian(&mut w, s);
            w
        };
        let (mats, biases) = match variant {
            Variant::Opu => {
                let wr = mat(1.0, rng);
                let wi = mat(1.0, rng);
                let mut br = vec![0.0f32; m];
                let mut bi = vec![0.0f32; m];
                rng.fill_gaussian(&mut br, 1.0);
                rng.fill_gaussian(&mut bi, 1.0);
                (vec![wr, wi], vec![br, bi])
            }
            Variant::Gauss | Variant::GaussEig => {
                // Frequencies ~ N(0, 1/sigma^2) approximate the Gaussian
                // kernel of bandwidth sigma (Rahimi-Recht).
                let w = mat(1.0 / sigma, rng);
                let mut b = vec![0.0f32; m];
                rng.fill_uniform(&mut b, 0.0, 2.0 * std::f32::consts::PI);
                (vec![w], vec![b])
            }
            Variant::Match => (Vec::new(), Vec::new()),
        };
        RfParams { variant, d, m, mats, biases }
    }
}

/// CPU implementation of the feature maps — same math as
/// `python/compile/kernels/ref.py`.
///
/// `Clone + Send + Sync` by construction (plain owned buffers): the
/// sharded coordinator hands one clone to every feature shard (and to
/// every sampler worker in inline mode), so the map must be free of
/// interior mutability and thread affinity. A compile-time assertion
/// below pins this.
#[derive(Clone, Debug)]
pub struct CpuFeatureMap {
    pub params: RfParams,
}

// The sharded pipeline moves CpuFeatureMap clones across threads; fail
// the build (not the run) if that ever stops being possible.
const _: () = {
    const fn assert_shardable<T: Clone + Send + Sync>() {}
    assert_shardable::<CpuFeatureMap>();
    assert_shardable::<RfParams>();
};

impl CpuFeatureMap {
    pub fn new(params: RfParams) -> Self {
        CpuFeatureMap { params }
    }

    /// Map a row-major batch `x` of shape (batch, d) into `out` of shape
    /// (batch, m).
    pub fn map_batch(&self, x: &[f32], batch: usize, out: &mut [f32]) {
        let p = &self.params;
        assert_eq!(x.len(), batch * p.d);
        assert_eq!(out.len(), batch * p.m);
        match p.variant {
            Variant::Gauss | Variant::GaussEig => {
                let scale = (2.0 / p.m as f32).sqrt();
                let w = &p.mats[0];
                let b = &p.biases[0];
                for r in 0..batch {
                    let xr = &x[r * p.d..(r + 1) * p.d];
                    let or = &mut out[r * p.m..(r + 1) * p.m];
                    or.copy_from_slice(b);
                    // Accumulate x_j * W[j, :] row-wise (W row-major d x m):
                    // better locality than per-output dot products.
                    for (j, &xj) in xr.iter().enumerate() {
                        if xj == 0.0 {
                            continue; // adjacency inputs are sparse 0/1
                        }
                        let wrow = &w[j * p.m..(j + 1) * p.m];
                        for (o, &wv) in or.iter_mut().zip(wrow) {
                            *o += xj * wv;
                        }
                    }
                    for o in or.iter_mut() {
                        *o = scale * o.cos();
                    }
                }
            }
            Variant::Opu => {
                let scale = 1.0 / (p.m as f32).sqrt();
                let (wr, wi) = (&p.mats[0], &p.mats[1]);
                let (br, bi) = (&p.biases[0], &p.biases[1]);
                let mut im = vec![0.0f32; p.m];
                for r in 0..batch {
                    let xr = &x[r * p.d..(r + 1) * p.d];
                    let or = &mut out[r * p.m..(r + 1) * p.m];
                    or.copy_from_slice(br);
                    im.copy_from_slice(bi);
                    for (j, &xj) in xr.iter().enumerate() {
                        if xj == 0.0 {
                            continue;
                        }
                        let wr_row = &wr[j * p.m..(j + 1) * p.m];
                        let wi_row = &wi[j * p.m..(j + 1) * p.m];
                        for idx in 0..p.m {
                            or[idx] += xj * wr_row[idx];
                            im[idx] += xj * wi_row[idx];
                        }
                    }
                    for (o, &i_v) in or.iter_mut().zip(im.iter()) {
                        *o = scale * (*o * *o + i_v * i_v);
                    }
                }
            }
            Variant::Match => panic!("phi_match is not a dense feature map"),
        }
    }
}

/// Analytic cost model of the physical OPU (DESIGN.md §2): a projection
/// takes constant wall-clock time regardless of d and m (within the
/// device's ~1e6 dimension limits). LightOn reports O(100 us) per
/// projection at full frame rate; Fig. 2 (right)'s "constant in k" series
/// is regenerated from this model while the simulation measures the
/// O(m k^2) software path.
pub const OPU_SECONDS_PER_PROJECTION: f64 = 1e-4;

pub fn opu_model_time(n_projections: usize) -> f64 {
    n_projections as f64 * OPU_SECONDS_PER_PROJECTION
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn naive_gauss(x: &[f32], d: usize, m: usize, w: &[f32], b: &[f32]) -> Vec<f32> {
        let batch = x.len() / d;
        let mut out = vec![0.0f32; batch * m];
        for r in 0..batch {
            for c in 0..m {
                let mut acc = b[c];
                for j in 0..d {
                    acc += x[r * d + j] * w[j * m + c];
                }
                out[r * m + c] = (2.0 / m as f32).sqrt() * acc.cos();
            }
        }
        out
    }

    #[test]
    fn cpu_gauss_matches_naive() {
        check::check("cpu-gauss", 0xD1, 30, |rng| {
            let (batch, d, m) = (1 + rng.usize(8), 1 + rng.usize(16), 1 + rng.usize(40));
            let params = RfParams::generate(Variant::Gauss, d, m, 1.0, rng);
            let mut x = vec![0.0f32; batch * d];
            rng.fill_gaussian(&mut x, 1.0);
            let mut out = vec![0.0f32; batch * m];
            CpuFeatureMap::new(params.clone()).map_batch(&x, batch, &mut out);
            let want = naive_gauss(&x, d, m, &params.mats[0], &params.biases[0]);
            check::assert_allclose(&out, &want, 1e-5, 1e-5);
        });
    }

    #[test]
    fn cpu_opu_nonnegative_and_scaled() {
        check::check("cpu-opu", 0xD2, 30, |rng| {
            let (batch, d, m) = (1 + rng.usize(8), 1 + rng.usize(16), 1 + rng.usize(40));
            let params = RfParams::generate(Variant::Opu, d, m, 1.0, rng);
            let mut x = vec![0.0f32; batch * d];
            for v in x.iter_mut() {
                *v = rng.bool(0.5) as u8 as f32;
            }
            let mut out = vec![0.0f32; batch * m];
            CpuFeatureMap::new(params).map_batch(&x, batch, &mut out);
            assert!(out.iter().all(|&v| v >= 0.0));
        });
    }

    #[test]
    fn opu_kernel_closed_form() {
        // Same law as the python test: for b = 0 and unit-variance complex
        // gaussian W, E[phi(x).phi(y)] -> 4 (||x||^2||y||^2 + <x,y>^2) / m
        // after accounting for the m^{-1/2} scaling (dot over m entries).
        let mut rng = Rng::new(99);
        let (d, m) = (4, 120_000);
        let mut params = RfParams::generate(Variant::Opu, d, m, 1.0, &mut rng);
        params.biases[0].fill(0.0);
        params.biases[1].fill(0.0);
        let x = [0.5f32, -1.0, 0.25, 2.0];
        let y = [1.0f32, 1.0, -0.5, 0.0];
        let mut input = Vec::new();
        input.extend_from_slice(&x);
        input.extend_from_slice(&y);
        let mut out = vec![0.0f32; 2 * m];
        CpuFeatureMap::new(params).map_batch(&input, 2, &mut out);
        let dot: f64 = (0..m).map(|i| out[i] as f64 * out[m + i] as f64).sum();
        let nx2: f64 = x.iter().map(|&v| (v * v) as f64).sum();
        let ny2: f64 = y.iter().map(|&v| (v * v) as f64).sum();
        let ip: f64 = x.iter().zip(&y).map(|(&a, &b)| (a * b) as f64).sum();
        let exact = 4.0 * (nx2 * ny2 + ip * ip);
        assert!((dot - exact).abs() / exact < 0.05, "{dot} vs {exact}");
    }

    #[test]
    fn gauss_kernel_approximation() {
        // phi(x).phi(y) ~ exp(-||x-y||^2 / (2 sigma^2))
        let mut rng = Rng::new(5);
        let (d, m, sigma) = (6, 80_000, 1.5f32);
        let params = RfParams::generate(Variant::Gauss, d, m, sigma, &mut rng);
        let mut xy = vec![0.0f32; 2 * d];
        rng.fill_gaussian(&mut xy, 0.7);
        let mut out = vec![0.0f32; 2 * m];
        CpuFeatureMap::new(params).map_batch(&xy, 2, &mut out);
        let dot: f64 = (0..m).map(|i| out[i] as f64 * out[m + i] as f64).sum();
        let dist2: f64 = (0..d)
            .map(|j| ((xy[j] - xy[d + j]) as f64).powi(2))
            .sum();
        let exact = (-dist2 / (2.0 * sigma as f64 * sigma as f64)).exp();
        assert!((dot - exact).abs() < 0.03, "{dot} vs {exact}");
    }

    #[test]
    fn variant_parse_roundtrip_and_errors() {
        assert_eq!(Variant::parse("opu").unwrap(), Variant::Opu);
        assert_eq!(Variant::parse("gauss").unwrap(), Variant::Gauss);
        assert_eq!(Variant::parse("gaussian").unwrap(), Variant::Gauss);
        assert_eq!(Variant::parse("gauss-eig").unwrap(), Variant::GaussEig);
        assert_eq!(Variant::parse("eig").unwrap(), Variant::GaussEig);
        assert_eq!(Variant::parse("match").unwrap(), Variant::Match);
        for v in [Variant::Opu, Variant::Gauss, Variant::GaussEig, Variant::Match] {
            assert_eq!(Variant::parse(v.name()).unwrap(), v);
        }
        let err = Variant::parse("laser").unwrap_err().to_string();
        assert!(err.contains("unknown variant") && err.contains("laser"), "{err}");
        assert!(Variant::parse("").is_err());
        assert!(Variant::parse("OPU").is_err(), "names are case-sensitive");
    }

    /// phi_OPU on a hand-computed graphlet with hand-picked parameters.
    /// Path 0-1-2 at k=3: flat adjacency x has exactly 4 ones (entries
    /// (0,1),(1,0),(1,2),(2,1)). With Wr = 1, Wi = 0.5 everywhere:
    ///   Re_j = 4 + br_j,  Im_j = 2 + bi_j,
    ///   phi_j = (Re_j^2 + Im_j^2) / sqrt(m).
    #[test]
    fn opu_map_matches_hand_computation_on_path_graphlet() {
        let mut g = Graphlet::empty(3);
        g.set_edge(0, 1);
        g.set_edge(1, 2);
        let (d, m) = (9usize, 2usize);
        let params = RfParams {
            variant: Variant::Opu,
            d,
            m,
            mats: vec![vec![1.0; d * m], vec![0.5; d * m]],
            biases: vec![vec![1.0, 0.0], vec![0.0, 2.0]],
        };
        let mut x = vec![0.0f32; d];
        Variant::Opu.write_input(&g, &mut x);
        assert_eq!(x.iter().filter(|&&v| v == 1.0).count(), 4);
        let mut out = vec![0.0f32; m];
        CpuFeatureMap::new(params).map_batch(&x, 1, &mut out);
        let scale = 1.0 / (m as f32).sqrt();
        // Feature 0: Re = 4 + 1 = 5, Im = 2 + 0 = 2 -> 29 / sqrt(2).
        // Feature 1: Re = 4 + 0 = 4, Im = 2 + 2 = 4 -> 32 / sqrt(2).
        check::assert_allclose(&out, &[29.0 * scale, 32.0 * scale], 1e-6, 1e-6);
    }

    /// phi_Gs on a hand-computed graphlet: the triangle at k=3 flattens
    /// to 6 ones, so with W = 0.25 and b = 0.5 every feature is
    /// sqrt(2/m) * cos(6 * 0.25 + 0.5) = sqrt(2/m) * cos(2).
    #[test]
    fn gauss_map_matches_hand_computation_on_triangle_graphlet() {
        let mut g = Graphlet::empty(3);
        g.set_edge(0, 1);
        g.set_edge(1, 2);
        g.set_edge(0, 2);
        let (d, m) = (9usize, 3usize);
        let params = RfParams {
            variant: Variant::Gauss,
            d,
            m,
            mats: vec![vec![0.25; d * m]],
            biases: vec![vec![0.5; m]],
        };
        let mut x = vec![0.0f32; d];
        Variant::Gauss.write_input(&g, &mut x);
        assert_eq!(x.iter().filter(|&&v| v == 1.0).count(), 6);
        let mut out = vec![0.0f32; m];
        CpuFeatureMap::new(params).map_batch(&x, 1, &mut out);
        let want = (2.0f32 / m as f32).sqrt() * 2.0f32.cos();
        check::assert_allclose(&out, &[want, want, want], 1e-6, 1e-6);
    }

    #[test]
    fn cpu_map_clones_compute_identical_features() {
        // The sharded pipeline relies on clones being interchangeable.
        let mut rng = Rng::new(12);
        let params = RfParams::generate(Variant::Opu, 9, 32, 1.0, &mut rng);
        let map = CpuFeatureMap::new(params);
        let clone = map.clone();
        let mut x = vec![0.0f32; 4 * 9];
        for v in x.iter_mut() {
            *v = rng.bool(0.4) as u8 as f32;
        }
        let mut a = vec![0.0f32; 4 * 32];
        let mut b = vec![0.0f32; 4 * 32];
        map.map_batch(&x, 4, &mut a);
        clone.map_batch(&x, 4, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn variant_io_dims() {
        assert_eq!(Variant::Opu.input_dim(6), 36);
        assert_eq!(Variant::GaussEig.input_dim(6), 6);
        let mut g = Graphlet::empty(3);
        g.set_edge(0, 1);
        let mut buf = vec![0.0f32; 9];
        Variant::Gauss.write_input(&g, &mut buf);
        assert_eq!(buf[1], 1.0);
        let mut ebuf = vec![0.0f32; 3];
        Variant::GaussEig.write_input(&g, &mut ebuf);
        // Eigenvalues of a single edge + isolated node: -1, 0, 1 sorted.
        check::assert_allclose(&ebuf, &[-1.0, 0.0, 1.0], 1e-5, 1e-5);
    }

    #[test]
    fn opu_cost_model_is_constant_in_dims() {
        assert_eq!(opu_model_time(10), 10.0 * OPU_SECONDS_PER_PROJECTION);
    }
}
