//! Jacobi eigenvalue solver for small symmetric matrices.
//!
//! The Gs+eig variant (paper §3.3) feeds the *sorted eigenvalues* of a
//! graphlet's adjacency matrix to the Gaussian feature map. Graphlets are
//! k <= 8, so the classical cyclic Jacobi rotation method is exact enough
//! and allocation-light — and crucially it keeps eigenvalues out of the
//! lowered HLO (CPU LAPACK custom-calls are not loadable by xla_extension
//! 0.5.1; see python/compile/model.py).

/// Sorted (ascending) eigenvalues of the symmetric `n x n` matrix `a`
/// (row-major, only assumed symmetric — the strict upper triangle is
/// trusted).
pub fn sorted_eigenvalues(a: &[f64], n: usize) -> Vec<f64> {
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    // Cyclic Jacobi sweeps until off-diagonal mass is negligible.
    for _sweep in 0..64 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q.
                for i in 0..n {
                    let aip = m[i * n + p];
                    let aiq = m[i * n + q];
                    m[i * n + p] = c * aip - s * aiq;
                    m[i * n + q] = s * aip + c * aiq;
                }
                for i in 0..n {
                    let api = m[p * n + i];
                    let aqi = m[q * n + i];
                    m[p * n + i] = c * api - s * aqi;
                    m[q * n + i] = s * api + c * aqi;
                }
            }
        }
    }
    let mut vals: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
    vals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graphlet;
    use crate::util::check;

    fn assert_close(got: &[f64], want: &[f64], tol: f64) {
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < tol, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = [3.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0, 0.0, 2.0];
        assert_close(&sorted_eigenvalues(&a, 3), &[-1.0, 2.0, 3.0], 1e-12);
    }

    #[test]
    fn single_edge() {
        // Adjacency of K2: eigenvalues -1, 1.
        let a = [0.0, 1.0, 1.0, 0.0];
        assert_close(&sorted_eigenvalues(&a, 2), &[-1.0, 1.0], 1e-12);
    }

    #[test]
    fn complete_graph_spectrum() {
        // K_n adjacency: eigenvalues (n-1) once and -1 with multiplicity
        // n-1.
        for n in 2..=8 {
            let mut a = vec![1.0; n * n];
            for i in 0..n {
                a[i * n + i] = 0.0;
            }
            let vals = sorted_eigenvalues(&a, n);
            for v in &vals[..n - 1] {
                assert!((v + 1.0).abs() < 1e-9, "{vals:?}");
            }
            assert!((vals[n - 1] - (n - 1) as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn path_graph_spectrum() {
        // P_n eigenvalues: 2 cos(pi i / (n+1)), i = 1..n.
        let n = 5;
        let mut g = Graphlet::empty(n);
        for i in 0..n - 1 {
            g.set_edge(i, i + 1);
        }
        let vals = sorted_eigenvalues(&g.adj_f64(), n);
        let mut want: Vec<f64> = (1..=n)
            .map(|i| 2.0 * (std::f64::consts::PI * i as f64 / (n + 1) as f64).cos())
            .collect();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_close(&vals, &want, 1e-9);
    }

    #[test]
    fn trace_and_frobenius_preserved() {
        check::check("eig-invariants", 0xE1, 100, |rng| {
            let n = 2 + rng.usize(7);
            // Random symmetric matrix.
            let mut a = vec![0.0f64; n * n];
            for i in 0..n {
                for j in i..n {
                    let v = rng.gaussian();
                    a[i * n + j] = v;
                    a[j * n + i] = v;
                }
            }
            let vals = sorted_eigenvalues(&a, n);
            let trace: f64 = (0..n).map(|i| a[i * n + i]).sum();
            let fro: f64 = a.iter().map(|v| v * v).sum();
            let sum: f64 = vals.iter().sum();
            let sum2: f64 = vals.iter().map(|v| v * v).sum();
            assert!((trace - sum).abs() < 1e-8, "trace {trace} vs {sum}");
            assert!((fro - sum2).abs() < 1e-8, "fro {fro} vs {sum2}");
        });
    }

    #[test]
    fn eigenvalues_are_permutation_invariant() {
        check::check("eig-perm", 0xE2, 100, |rng| {
            let k = 2 + rng.usize(7);
            let n_pairs = k * (k - 1) / 2;
            let g = Graphlet::from_bits(k, (rng.next_u64() & ((1u64 << n_pairs) - 1)) as u32);
            let mut perm: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut perm);
            let h = g.permute(&perm);
            let vg = sorted_eigenvalues(&g.adj_f64(), k);
            let vh = sorted_eigenvalues(&h.adj_f64(), k);
            assert_close(&vg, &vh, 1e-9);
        });
    }
}
