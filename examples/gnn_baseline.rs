//! GIN baseline (Fig 1 right's GNN comparison): train the 5-layer GIN
//! over the AOT-compiled train-step artifact, log the loss curve, and
//! report structure-only test accuracy on the SBM task.
//!
//! ```bash
//! make artifacts && cargo run --release --example gnn_baseline -- --r 1.2
//! ```

use anyhow::Result;
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::gnn::{GinConfig, GinModel};
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::util::{Args, Rng};

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed: u64 = args.parse_or("seed", 0u64);
    let r = args.parse_or("r", 1.2f64);
    let per_class = args.parse_or("per-class", 120usize);
    let steps = args.parse_or("steps", 400usize);

    let engine = Engine::new(&artifacts_dir())?;
    println!("engine: PJRT ({})", engine.platform());
    let ds = SbmConfig { r, per_class, ..Default::default() }.generate(&mut Rng::new(seed));
    println!("dataset: {}", ds.summary());
    let split = ds.split(0.8, &mut Rng::new(seed ^ 0xACC));
    let cfg = GinConfig { steps, seed, log_every: steps / 20 + 1 };
    let (acc, curve) = GinModel::train_and_eval(&engine, &ds, &split, &cfg)?;
    println!("loss curve:");
    for (step, loss) in &curve {
        println!("  step {step:>4}: {loss:.4}");
    }
    println!("GIN test accuracy: {acc:.3}");
    Ok(())
}
