//! Fig 3: the real-data protocol (D&D and Reddit-Binary) — accuracy vs m
//! against the exact graphlet-kernel baseline, k = 7, s = 4000 at full
//! scale.
//!
//! The default datasets are the structure-matched synthetic substitutes
//! (DESIGN.md §2); real TU-format data drops in via `--tu-dir`:
//!
//! ```bash
//! cargo run --release --example fig3_real -- --dataset dd
//! cargo run --release --example fig3_real -- --dataset reddit --scale full
//! cargo run --release --example fig3_real -- --dataset DD --tu-dir /data/TU
//! ```

use anyhow::Result;
use graphlet_rf::coordinator::EngineMode;
use graphlet_rf::experiments::{figures, ExpContext, Scale};
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dataset = args.str_or("dataset", "dd").to_string();
    let seed: u64 = args.parse_or("seed", 0u64);
    let scale = Scale::parse(args.str_or("scale", "quick"));
    let tu_dir = args.get("tu-dir").map(std::path::PathBuf::from);

    let engine = Engine::new(&artifacts_dir()).ok();
    let mut ctx = ExpContext::new(engine, std::path::PathBuf::from(args.str_or("out", "results")));
    if let Some(mode) = args.get("engine").map(EngineMode::parse).transpose()? {
        ctx.engine_mode = Some(mode);
    }
    figures::fig3(&ctx, &scale, &dataset, tu_dir.as_deref(), seed)?;
    Ok(())
}
