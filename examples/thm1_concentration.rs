//! Theorem 1 empirical verification: the GSA-phi embedding distance
//! concentrates around the MMD within the paper's bound
//! `4 m^{-1/2} sqrt(log(6/delta)) + 8 s^{-1/2} (1 + sqrt(2 log(3/delta)))`.
//!
//! ```bash
//! cargo run --release --example thm1_concentration
//! ```
//! Prints one row per (m, s) operating point and writes
//! `results/thm1.json`; asserts the bound holds in >= 1 - delta of trials.

use anyhow::Result;
use graphlet_rf::experiments::{thm1, ExpContext};
use graphlet_rf::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed: u64 = args.parse_or("seed", 0u64);
    let ctx = ExpContext::new(None, std::path::PathBuf::from(args.str_or("out", "results")));
    thm1::run(&ctx, seed)?;
    Ok(())
}
