//! End-to-end quickstart — the system-validation driver (DESIGN.md §5).
//!
//! Exercises every layer on a real small workload:
//!   1. generate an SBM graph-classification dataset (paper §4.1),
//!   2. random-walk-sample graphlets in parallel worker threads,
//!   3. embed them with simulated-OPU random features executed from the
//!      AOT-compiled XLA artifact over PJRT (L1/L2 build-time python,
//!      never imported here),
//!   4. train the linear SVM tail and report test accuracy + pipeline
//!      throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//! Falls back to the rust CPU feature engine when artifacts are missing.

use anyhow::Result;
use graphlet_rf::classify::{train_and_eval, TrainConfig};
use graphlet_rf::coordinator::{embed_dataset, EngineMode, GsaConfig};
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::util::{Args, Rng, Timer};

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed: u64 = args.parse_or("seed", 0u64);
    let r = args.parse_or("r", 1.2f64);
    let per_class = args.parse_or("per-class", 60usize);

    // PJRT engine if `make artifacts` has been run.
    let engine = match Engine::new(&artifacts_dir()) {
        Ok(e) => {
            println!("engine: PJRT ({})", e.platform());
            Some(e)
        }
        Err(e) => {
            println!("engine: rust CPU fallback ({e})");
            None
        }
    };

    let total = Timer::start();
    let ds = SbmConfig { r, per_class, ..Default::default() }.generate(&mut Rng::new(seed));
    println!("dataset: {}", ds.summary());

    let cfg = GsaConfig {
        k: args.parse_or("k", 6usize),
        s: args.parse_or("s", 2000usize),
        m: args.parse_or("m", 5000usize),
        shards: args.parse_or("shards", 1usize).max(1),
        engine: if engine.is_some() { EngineMode::Pjrt } else { EngineMode::CpuInline },
        seed,
        ..Default::default()
    };
    println!(
        "GSA-phi_OPU: k={} s={} m={} sampler={} batch={} shards={}",
        cfg.k, cfg.s, cfg.m, cfg.sampler, cfg.batch, cfg.shards
    );
    let (emb, metrics) = embed_dataset(&ds, &cfg, engine.as_ref())?;
    println!("pipeline: {}", metrics.report());

    let split = ds.split(0.8, &mut Rng::new(seed ^ 0xACC));
    let acc = train_and_eval(
        &emb,
        &ds.labels,
        cfg.m,
        &split.train,
        &split.test,
        &TrainConfig::default(),
    );
    println!(
        "test accuracy: {acc:.3} ({} train / {} test graphs)",
        split.train.len(),
        split.test.len()
    );
    println!("total wall time: {:.1}s", total.elapsed_secs());
    Ok(())
}
