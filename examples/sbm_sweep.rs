//! SBM accuracy sweeps: regenerates Fig 1 (left), Fig 1 (right) and
//! Fig 2 (left) of the paper.
//!
//! ```bash
//! cargo run --release --example sbm_sweep -- fig1-left            # quick scale
//! cargo run --release --example sbm_sweep -- fig1-right --scale full
//! cargo run --release --example sbm_sweep -- fig2-left
//! cargo run --release --example sbm_sweep -- all
//! ```
//! Results print as rows and land in `results/<figure>.json`.

use anyhow::Result;
use graphlet_rf::coordinator::EngineMode;
use graphlet_rf::experiments::{figures, ExpContext, Scale};
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let which = args.positional().first().map(|s| s.as_str()).unwrap_or("all");
    let seed: u64 = args.parse_or("seed", 0u64);
    let scale = Scale::parse(args.str_or("scale", "quick"));

    let engine = Engine::new(&artifacts_dir()).ok();
    let mut ctx = ExpContext::new(engine, std::path::PathBuf::from(args.str_or("out", "results")));
    if let Some(mode) = args.get("engine").map(EngineMode::parse).transpose()? {
        ctx.engine_mode = Some(mode);
    }

    if matches!(which, "fig1-left" | "all") {
        figures::fig1_left(&ctx, &scale, seed)?;
    }
    if matches!(which, "fig1-right" | "all") {
        figures::fig1_right(&ctx, &scale, seed)?;
    }
    if matches!(which, "fig2-left" | "all") {
        figures::fig2_left(&ctx, &scale, seed)?;
    }
    Ok(())
}
