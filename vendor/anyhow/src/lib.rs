//! Offline stand-in for the `anyhow` crate (API-compatible subset).
//!
//! Provides exactly the surface the workspace uses:
//!
//! - [`Error`]: an opaque error value carrying a context chain and an
//!   optional source error. Like upstream, it deliberately does **not**
//!   implement `std::error::Error` — that is what makes the blanket
//!   `From<E: std::error::Error>` conversion (and therefore `?` on any
//!   std error) coherent.
//! - [`Result<T>`]: alias with `Error` as the default error type.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` (both
//!   std-error and `anyhow::Error` variants) and on `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros with `format!`-style
//!   arguments.
//!
//! `Debug` prints the full chain on one line (`outer: inner: root`),
//! matching how the repo's binaries surface errors from `main`.

use std::error::Error as StdError;
use std::fmt;

/// An opaque error: a stack of context messages plus an optional
/// underlying source error.
pub struct Error {
    /// Context messages, outermost (most recently attached) first. The
    /// last entry is the original message.
    chain: Vec<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], source: None }
    }

    /// Attach an outer context message (most recent shown first).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (what `Display` shows).
    fn head(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("unknown error")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            return write!(f, "{:?}", self);
        }
        f.write_str(self.head())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))?;
        // The last chain entry already rendered the source's Display;
        // append anything deeper in the std source chain.
        let mut cause = self.source.as_deref().and_then(|e| e.source());
        while let Some(c) = cause {
            write!(f, ": {c}")?;
            cause = c.source();
        }
        Ok(())
    }
}

// Blanket conversion: lets `?` lift any std error into `Error`. Coherent
// because `Error` itself never implements `std::error::Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error { chain: vec![err.to_string()], source: Some(Box::new(err)) }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error value with an additional message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with a lazily evaluated message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Same extension for results that already carry an `anyhow::Error`
// (coherent with the impl above because `Error: !StdError`).
impl<T> Context<T> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from `format!`-style arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Error::from(io_err()).context("opening manifest");
        assert_eq!(e.to_string(), "opening manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("opening manifest") && dbg.contains("missing file"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_option_and_error() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("layer one").unwrap_err();
        let e2 = Err::<(), Error>(e).with_context(|| "layer two").unwrap_err();
        assert_eq!(e2.to_string(), "layer two");
        assert!(format!("{e2:?}").starts_with("layer two: layer one"));
        let n: Option<u32> = None;
        assert_eq!(n.context("was none").unwrap_err().to_string(), "was none");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn b() -> Result<()> {
            bail!("bad value {}", 7);
        }
        assert_eq!(b().unwrap_err().to_string(), "bad value 7");

        fn e(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            Ok(x)
        }
        assert_eq!(e(3).unwrap(), 3);
        assert_eq!(e(12).unwrap_err().to_string(), "x too big: 12");
        assert!(e(5).unwrap_err().to_string().contains("x != 5"));
        let msg = anyhow!("plain");
        assert_eq!(msg.to_string(), "plain");
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_bounds<T: Send + Sync>() {}
        assert_bounds::<Error>();
    }
}
