//! Offline stub of the `xla` PJRT bindings.
//!
//! Exposes the exact type/method surface `rust/src/runtime/` compiles
//! against. There is no PJRT runtime behind it: [`PjRtClient::cpu`]
//! returns an error, so `Engine::new` fails cleanly at runtime, every
//! caller falls back to the rust CPU feature engines, and the
//! PJRT-dependent tests skip. Replacing this path dependency with the
//! real `xla` crate re-enables PJRT with no source changes.
//!
//! Methods that are only reachable *after* a client exists (execution,
//! transfers) still return honest `Err` values rather than panicking, so
//! any future partial implementation degrades gracefully.

use std::error::Error as StdError;
use std::fmt;

/// Error type of the stub; converts into `anyhow::Error` via the
/// standard-error blanket conversion.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: the vendored xla stub provides no PJRT runtime \
             (swap vendor/xla for the real crate to enable it)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Error {}

/// Result alias matching the real crate's signatures.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types accepted by literals and host buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal value (opaque in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    elements: usize,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elements: data.len() }
    }

    /// Reshape to the given dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.elements {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.elements
            )));
        }
        Ok(self.clone())
    }

    /// Decompose a tuple literal (unreachable without a runtime).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    /// Copy out as a host vector (unreachable without a runtime).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error(format!(
            "parsing HLO text {path}: the vendored xla stub has no HLO parser"
        )))
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer handle (never constructible in the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (never constructible in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle. The stub's constructor always fails — this is the
/// single choke point that routes the whole system onto the CPU engines.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructor_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("no PJRT runtime"), "{err}");
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let lit = Literal::vec1(&[0.0f32; 6]);
        assert!(lit.reshape(&[2, 3]).is_ok());
        assert!(lit.reshape(&[4, 2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
