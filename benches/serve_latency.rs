//! Bench: serving-path latency and throughput baseline.
//!
//! Spins the serve daemon on an ephemeral loopback port with a fixed
//! seed and workload, drives it with the serve-bench client (4
//! connections x 32 requests, cold pass then warm/cached pass), and
//! prints throughput plus p50/p99 latency per pass. Future PRs compare
//! against these numbers before touching the serve or streaming path.
//!
//! PJRT artifacts are used when present (`make artifacts`); otherwise
//! the CPU feature engine serves, which is still the same wire path and
//! cache — only the feature math moves off the artifact.

use graphlet_rf::coordinator::{EngineMode, GsaConfig};
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::serve::{run_bench, send_shutdown, ServeConfig, Server};

fn main() {
    let engine = Engine::new(&artifacts_dir()).ok();
    let gsa = GsaConfig {
        k: 6,
        s: 500,
        m: 1000,
        batch: 256,
        shards: 2,
        workers: 4,
        engine: if engine.is_some() { EngineMode::Pjrt } else { EngineMode::Cpu },
        seed: 7,
        ..Default::default()
    };
    println!(
        "# serve_latency (engine={:?}, k={}, s={}, m={}, shards={}, workers={})",
        gsa.engine, gsa.k, gsa.s, gsa.m, gsa.shards, gsa.workers
    );
    let server = Server::bind("127.0.0.1:0", ServeConfig { gsa, ..Default::default() },
                              engine.as_ref())
        .expect("bind serve daemon");
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run().expect("serve run"));

    let run = run_bench(&addr, 4, 32, 7).expect("bench run");
    for (label, report) in &run.passes {
        println!("serve_latency/{label}  {}", report.line());
        assert_eq!(report.errors, 0, "{label} pass must be error-free");
    }
    let warm = run.get("warm_l1").expect("warm_l1 pass");
    assert_eq!(warm.recomputed_graphs, 0, "warm_l1 pass must be fully cached");
    println!("{}", run.json());

    send_shutdown(&addr).expect("shutdown");
    handle.join().expect("server thread");
}
