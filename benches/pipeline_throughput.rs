//! Bench: end-to-end coordinator throughput (samples/second through the
//! full sample -> batch -> feature -> accumulate pipeline), across engine
//! modes, batch sizes, and — the scaling axis — feature-engine shard
//! counts. This is the L3 §Perf driver — EXPERIMENTS.md quotes its
//! numbers; the shard sweep is the headline: with enough sampler
//! workers, `shards=4` must out-run `shards=1` on the CPU engine because
//! the single feature thread is the unsharded pipeline's bottleneck.

#[allow(dead_code)] // BenchLog is used by the table1/fastrf benches.
mod bench_harness;

use bench_harness::bench_case;
use graphlet_rf::coordinator::{embed_dataset, EngineMode, GsaConfig};
use graphlet_rf::features::Variant;
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::util::Rng;

/// L2 §Perf ablation: fused on-device mean (embed artifact, (s,d)->(m,))
/// vs the streaming per-batch path ((B,d)->(B,m) + host-side scatter).
/// The fused path avoids shipping s*m floats back per graph.
fn bench_fused_vs_streaming(engine: &Engine) {
    use graphlet_rf::features::RfParams;
    use graphlet_rf::runtime::{HostTensor, RfExecutor};
    let (d, m, s) = (36usize, 5000usize, 2000usize);
    let mut rng = Rng::new(5);
    let params = RfParams::generate(Variant::Opu, d, m, 1.0, &mut rng);
    let mut x = vec![0.0f32; s * d];
    for v in x.iter_mut() {
        *v = rng.bool(0.3) as u8 as f32;
    }
    // Streaming path: 8 batches of 256 through the rf artifact, mean on
    // host (what the pipeline does, minus sampling).
    let exec = RfExecutor::new(engine, "xla", &params, 256).unwrap();
    let t_stream = bench_case("embed_one_graph", "streaming_b256", 1, 5, || {
        let mut sum = vec![0.0f32; m];
        for chunk in 0..(s / 256) {
            let rows = &x[chunk * 256 * d..(chunk + 1) * 256 * d];
            let y = exec.map(engine, rows, 256).unwrap();
            for r in 0..256 {
                for (a, &v) in sum.iter_mut().zip(&y[r * m..(r + 1) * m]) {
                    *a += v;
                }
            }
        }
        std::hint::black_box(sum);
    });
    // Fused path: one call, mean computed on device.
    let art = engine.load("embed_opu_xla_d36_m5000_s2000").unwrap();
    let inputs = vec![
        HostTensor::F32(x.clone()),
        HostTensor::F32(params.mats[0].clone()),
        HostTensor::F32(params.mats[1].clone()),
        HostTensor::F32(params.biases[0].clone()),
        HostTensor::F32(params.biases[1].clone()),
    ];
    let t_fused = bench_case("embed_one_graph", "fused_embed_s2000", 1, 5, || {
        std::hint::black_box(art.execute(&inputs).unwrap());
    });
    println!(
        "  -> fused/streaming speedup: {:.2}x ({} vs {})",
        t_stream / t_fused,
        bench_harness::fmt(t_stream),
        bench_harness::fmt(t_fused)
    );
    // L1 ablation: the pallas-impl artifact for the same fused embedding.
    if let Ok(art_p) = engine.load("embed_opu_pallas_d36_m5000_s2000") {
        let t_pallas = bench_case("embed_one_graph", "fused_embed_pallas", 1, 3, || {
            std::hint::black_box(art_p.execute(&inputs).unwrap());
        });
        println!(
            "  -> pallas-interpret vs fused-xla: {:.2}x slower (expected: \
             interpret-mode pallas lowers to loop HLO; the kernel targets TPU)",
            t_pallas / t_fused
        );
    }
}

/// The shard-sweep axis: same workload, growing feature-shard counts.
/// Prints the speedup of each shard count over shards=1.
fn bench_shard_sweep(ds: &graphlet_rf::data::Dataset, engine: Option<&Engine>) {
    println!("# shard sweep (m=2000, s=1000, workers=8)");
    for (mode, name) in [(EngineMode::Cpu, "cpu"), (EngineMode::Pjrt, "pjrt")] {
        if mode == EngineMode::Pjrt && engine.is_none() {
            eprintln!("skipping pjrt shard sweep (no artifacts)");
            continue;
        }
        let mut t1 = None;
        for shards in [1usize, 2, 4] {
            let cfg = GsaConfig {
                k: 6,
                s: 1000,
                m: 2000,
                batch: 256,
                variant: Variant::Opu,
                engine: mode,
                workers: 8,
                shards,
                seed: 1,
                ..Default::default()
            };
            let samples = ds.len() * cfg.s;
            let t = bench_case("pipeline", &format!("{name}_shards{shards}"), 1, 3, || {
                let (emb, _) = embed_dataset(ds, &cfg, engine).unwrap();
                std::hint::black_box(emb);
            });
            if shards == 1 {
                t1 = Some(t);
            }
            println!(
                "  -> {name} shards={shards}: {:.0} samples/s ({:.2}x vs shards=1)",
                samples as f64 / t,
                t1.unwrap_or(t) / t
            );
        }
    }
}

fn main() {
    let ds = SbmConfig { per_class: 10, r: 1.2, ..Default::default() }
        .generate(&mut Rng::new(3));
    let engine = Engine::new(&artifacts_dir()).ok();
    if let Some(e) = &engine {
        bench_fused_vs_streaming(e);
    }
    bench_shard_sweep(&ds, engine.as_ref());
    let s = 1000usize;

    for (mode, name) in [
        (EngineMode::Cpu, "cpu"),
        (EngineMode::CpuInline, "cpu-inline"),
        (EngineMode::Pjrt, "pjrt"),
    ] {
        if mode == EngineMode::Pjrt && engine.is_none() {
            eprintln!("skipping pjrt (no artifacts)");
            continue;
        }
        for m in [1000usize, 5000] {
            let cfg = GsaConfig {
                k: 6,
                s,
                m,
                batch: 256,
                variant: Variant::Opu,
                engine: mode,
                seed: 1,
                ..Default::default()
            };
            let samples = ds.len() * s;
            let t = bench_case("pipeline", &format!("{name}_m{m}"), 1, 3, || {
                let (emb, _) = embed_dataset(&ds, &cfg, engine.as_ref()).unwrap();
                std::hint::black_box(emb);
            });
            println!(
                "  -> {name} m={m}: {:.0} samples/s ({} graphs x {s} samples)",
                samples as f64 / t,
                ds.len()
            );
        }
    }
}
