//! Shared mini bench harness for the `harness = false` benches
//! (criterion is unavailable in the offline build; this prints a
//! criterion-like report: warmup, median and spread over runs).
//!
//! [`BenchLog`] additionally collects each case's median into a
//! machine-readable `BENCH_<bench>.json` summary (median ns per
//! measured call, one entry per config) so the perf trajectory can be
//! compared across PRs instead of living only in scrollback. Baselines
//! are committed under `benches/baselines/`; re-running a bench
//! overwrites its file (override the directory with `BENCH_OUT_DIR`).

use std::path::PathBuf;
use std::time::Instant;

use graphlet_rf::util::Json;

/// Measure `f` and print a criterion-style line. Returns median seconds.
pub fn bench_case<F: FnMut()>(group: &str, name: &str, warmup: u32, runs: u32, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..runs.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let max = times[times.len() - 1];
    println!(
        "{group}/{name:<28} time: [{} {} {}]",
        fmt(min),
        fmt(median),
        fmt(max)
    );
    median
}

/// Collected medians for one bench binary, written as
/// `BENCH_<bench>.json`.
pub struct BenchLog {
    bench: String,
    cases: Vec<(String, String, f64)>,
}

impl BenchLog {
    pub fn new(bench: &str) -> BenchLog {
        BenchLog { bench: bench.to_string(), cases: Vec::new() }
    }

    /// Record one case's median wall-clock seconds (as returned by
    /// [`bench_case`]).
    pub fn record(&mut self, group: &str, name: &str, median_secs: f64) {
        self.cases.push((group.to_string(), name.to_string(), median_secs));
    }

    /// Write `BENCH_<bench>.json` into `$BENCH_OUT_DIR` (default:
    /// `benches/baselines/` in the repository) and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("BENCH_OUT_DIR").map(PathBuf::from).unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../benches/baselines")
        });
        std::fs::create_dir_all(&dir)?;
        let mut cases = Json::arr();
        for (group, name, secs) in &self.cases {
            cases.push(
                Json::obj()
                    .set("group", group.as_str())
                    .set("name", name.as_str())
                    .set("median_ns", (secs * 1e9).round()),
            );
        }
        let doc = Json::obj()
            .set("bench", self.bench.as_str())
            .set("unit", "median nanoseconds per measured call")
            .set("status", "measured")
            .set("cases", cases);
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, format!("{doc}\n"))?;
        Ok(path)
    }
}

pub fn fmt(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}
