//! Shared mini bench harness for the `harness = false` benches
//! (criterion is unavailable in the offline build; this prints a
//! criterion-like report: warmup, median and spread over runs).

use std::time::Instant;

/// Measure `f` and print a criterion-style line. Returns median seconds.
pub fn bench_case<F: FnMut()>(group: &str, name: &str, warmup: u32, runs: u32, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..runs.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let max = times[times.len() - 1];
    println!(
        "{group}/{name:<28} time: [{} {} {}]",
        fmt(min),
        fmt(median),
        fmt(max)
    );
    median
}

pub fn fmt(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}
