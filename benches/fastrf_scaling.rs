//! Bench: structured (SORF/FWHT) vs dense random features across the
//! (d, m) grid.
//!
//! The dense baseline is the cache-blocked kernel in
//! `graphlet_rf::fastrf::DenseMap` — `O(d·m)` per batch no matter how
//! well it is tiled. The SORF map costs `O(⌈m/p⌉ · p log p)` with
//! `p = 2^⌈log₂ d⌉`, so its advantage grows with d; the acceptance
//! point for this subsystem is d = 25 (k = 5 graphlets), m ≥ 2048,
//! where SORF must beat dense.
//!
//! Inputs are dense Gaussian vectors: the dense kernel's sparse-input
//! fast path (zero skipping on 0/1 adjacency rows) is a separate axis,
//! measured by `table1_complexity` — here both kernels do their full
//! nominal work.
//!
//! Emits `BENCH_fastrf_scaling.json` (median ns per batch call of 256
//! rows, per config) next to the other committed baselines; run with
//! `cargo bench --bench fastrf_scaling`.

mod bench_harness;

use bench_harness::{bench_case, BenchLog};
use graphlet_rf::fastrf::{DenseMap, SorfMap, SorfParams};
use graphlet_rf::features::{RfParams, Variant};
use graphlet_rf::util::Rng;

fn main() {
    let batch = 256usize;
    let mut rng = Rng::new(42);
    let mut log = BenchLog::new("fastrf_scaling");
    println!("# fastrf scaling: dense (cache-blocked) vs SORF (FWHT), batch = {batch}");
    for &(k, d) in &[(3usize, 9usize), (5, 25), (6, 36)] {
        for &m in &[512usize, 2048, 8192] {
            let mut x = vec![0.0f32; batch * d];
            rng.fill_gaussian(&mut x, 1.0);
            for variant in [Variant::Gauss, Variant::Opu] {
                let dense = DenseMap::new(RfParams::generate(variant, d, m, 0.1, &mut rng));
                let sorf = SorfMap::new(SorfParams::generate(variant, d, m, 0.1, &mut rng));
                let mut y = vec![0.0f32; batch * m];
                let name = format!("{}_k{k}_d{d}_m{m}", variant.name());
                let t_dense = bench_case("fastrf_dense", &name, 2, 7, || {
                    dense.map_batch(&x, batch, &mut y);
                });
                log.record("dense", &name, t_dense);
                let t_sorf = bench_case("fastrf_sorf", &name, 2, 7, || {
                    sorf.map_batch(&x, batch, &mut y);
                });
                log.record("sorf", &name, t_sorf);
                println!(
                    "  -> {name}: dense/sorf = {:.2}x {}",
                    t_dense / t_sorf.max(1e-12),
                    if t_sorf < t_dense { "(sorf wins)" } else { "(dense wins)" }
                );
            }
        }
    }
    println!(
        "\nacceptance point: opu/gauss at k=5 (d=25), m >= 2048 — sorf must win \
         (blocks of p=32, 3·log2(32) butterflies/element vs 25 madds/element)."
    );
    match log.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
}
