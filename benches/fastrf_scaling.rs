//! Bench: structured (SORF/FWHT) vs dense random features across the
//! (d, m) grid, with a batch-size × thread-count axis on the
//! batch-major SORF path.
//!
//! The dense baseline is the cache-blocked kernel in
//! `graphlet_rf::fastrf::DenseMap` — `O(d·m)` per batch no matter how
//! well it is tiled. The SORF map costs `O(⌈m/p⌉ · p log p)` with
//! `p = 2^⌈log₂ d⌉`, so its advantage grows with d; the acceptance
//! point for this subsystem is d = 25 (k = 5 graphlets), m ≥ 2048,
//! where SORF must beat dense.
//!
//! Three SORF execution shapes race per config:
//! - `sorf_scalar` — the pre-batch-major hot loop (block-outer,
//!   row-inner, scalar FWHT on one shared buffer), reconstructed
//!   faithfully in this file so the bar is the replaced code, not a
//!   degraded stand-in;
//! - `sorf_t1` — batch-major panels, serial (`map_batch`); the
//!   acceptance bar is `sorf_t1 ≤ sorf_scalar` at every (d, m, batch)
//!   point (the panel path must never lose to the row loop);
//! - `sorf_t{2,4}` — `map_batch_threads` with a 2- and 4-worker budget
//!   (independent blocks, or panel rows for single-block maps, split
//!   across scoped threads).
//!
//! All shapes produce bitwise-identical outputs (pinned by
//! `tests/fastrf_prop.rs`), so every ratio here is pure scheduling.
//!
//! Inputs are dense Gaussian vectors: the dense kernel's sparse-input
//! fast path (zero skipping on 0/1 adjacency rows) is a separate axis,
//! measured by `table1_complexity` — here both kernels do their full
//! nominal work.
//!
//! Emits `BENCH_fastrf_scaling.json` (median ns per batch call, per
//! config) next to the other committed baselines; run with
//! `cargo bench --bench fastrf_scaling`.

mod bench_harness;

use bench_harness::{bench_case, BenchLog};
use graphlet_rf::fastrf::{fwht_inplace, DenseMap, SorfMap, SorfParams, SORF_ROUNDS};
use graphlet_rf::features::{RfParams, Variant};
use graphlet_rf::util::Rng;

/// The historical (pre-batch-major) SORF hot loop, reconstructed from
/// the map's public parameters so the `sorf_scalar` bar measures the
/// implementation the refactor actually replaced: block-outer,
/// row-inner, one shared pad-size buffer, scalar in-place FWHT per
/// (row, block). Bitwise identical to `map_batch` (same per-element
/// arithmetic) — only the execution shape differs.
fn sorf_row_at_a_time(map: &SorfMap, x: &[f32], batch: usize, out: &mut [f32]) {
    fn project(xr: &[f32], signs: &[f32], block: usize, pad: usize, buf: &mut [f32]) {
        buf[..xr.len()].copy_from_slice(xr);
        buf[xr.len()..].fill(0.0);
        for round in 0..SORF_ROUNDS {
            let base = (block * SORF_ROUNDS + round) * pad;
            for (v, &sg) in buf.iter_mut().zip(&signs[base..base + pad]) {
                *v *= sg;
            }
            fwht_inplace(buf);
        }
    }
    let p = &map.params;
    let pad = p.padded;
    let mut buf = vec![0.0f32; pad];
    match p.variant {
        Variant::Gauss | Variant::GaussEig => {
            let scale = (2.0 / p.m as f32).sqrt();
            let inv_sp = 1.0 / (p.sigma * pad as f32);
            for block in 0..p.blocks {
                let lo = block * pad;
                let hi = ((block + 1) * pad).min(p.m);
                for r in 0..batch {
                    project(&x[r * p.d..(r + 1) * p.d], &p.signs[0], block, pad, &mut buf);
                    let or = &mut out[r * p.m + lo..r * p.m + hi];
                    for ((o, &z), &bj) in or.iter_mut().zip(buf.iter()).zip(&p.biases[0][lo..hi]) {
                        *o = scale * (z * inv_sp + bj).cos();
                    }
                }
            }
        }
        Variant::Opu => {
            let scale = 1.0 / (p.m as f32).sqrt();
            let inv_p = 1.0 / pad as f32;
            let mut ibuf = vec![0.0f32; pad];
            for block in 0..p.blocks {
                let lo = block * pad;
                let hi = ((block + 1) * pad).min(p.m);
                for r in 0..batch {
                    let xr = &x[r * p.d..(r + 1) * p.d];
                    project(xr, &p.signs[0], block, pad, &mut buf);
                    project(xr, &p.signs[1], block, pad, &mut ibuf);
                    let or = &mut out[r * p.m + lo..r * p.m + hi];
                    let it = or
                        .iter_mut()
                        .zip(buf.iter())
                        .zip(ibuf.iter())
                        .zip(&p.biases[0][lo..hi])
                        .zip(&p.biases[1][lo..hi]);
                    for ((((o, &zr), &zi), &brj), &bij) in it {
                        let re = zr * inv_p + brj;
                        let im = zi * inv_p + bij;
                        *o = scale * (re * re + im * im);
                    }
                }
            }
        }
        Variant::Match => unreachable!("bench never uses phi_match"),
    }
}

fn main() {
    let batches = [64usize, 256];
    let threads = [2usize, 4];
    let mut rng = Rng::new(42);
    let mut log = BenchLog::new("fastrf_scaling");
    println!(
        "# fastrf scaling: dense (cache-blocked) vs SORF (batch-major FWHT), \
         batch axis {batches:?}, thread axis {threads:?}"
    );
    let mut batch_never_loses = true;
    for &(k, d) in &[(3usize, 9usize), (5, 25), (6, 36)] {
        for &m in &[512usize, 2048, 8192] {
            for variant in [Variant::Gauss, Variant::Opu] {
                let dense = DenseMap::new(RfParams::generate(variant, d, m, 0.1, &mut rng));
                let sorf = SorfMap::new(SorfParams::generate(variant, d, m, 0.1, &mut rng));
                for &batch in &batches {
                    let mut x = vec![0.0f32; batch * d];
                    rng.fill_gaussian(&mut x, 1.0);
                    let mut y = vec![0.0f32; batch * m];
                    let name = format!("{}_k{k}_d{d}_m{m}_b{batch}", variant.name());
                    // Self-check before timing anything against it: the
                    // reconstructed scalar loop must match the real map
                    // bit for bit, or the regression bar is measuring a
                    // different computation.
                    {
                        let mut want = vec![0.0f32; batch * m];
                        sorf.map_batch(&x, batch, &mut want);
                        sorf_row_at_a_time(&sorf, &x, batch, &mut y);
                        assert_eq!(y, want, "scalar reconstruction drifted from map_batch: {name}");
                    }
                    let t_dense = bench_case("fastrf_dense", &name, 2, 7, || {
                        dense.map_batch(&x, batch, &mut y);
                    });
                    log.record("dense", &name, t_dense);
                    // Row-at-a-time: the historical hot loop the
                    // batch-major refactor replaced (reconstructed
                    // above), kept as the regression bar.
                    let t_scalar = bench_case("fastrf_sorf_scalar", &name, 2, 7, || {
                        sorf_row_at_a_time(&sorf, &x, batch, &mut y);
                    });
                    log.record("sorf_scalar", &name, t_scalar);
                    let t_batch = bench_case("fastrf_sorf_t1", &name, 2, 7, || {
                        sorf.map_batch(&x, batch, &mut y);
                    });
                    log.record("sorf_t1", &name, t_batch);
                    for &t in &threads {
                        let t_par = bench_case(&format!("fastrf_sorf_t{t}"), &name, 2, 7, || {
                            sorf.map_batch_threads(&x, batch, &mut y, t);
                        });
                        log.record(&format!("sorf_t{t}"), &name, t_par);
                    }
                    if t_batch > t_scalar {
                        batch_never_loses = false;
                    }
                    println!(
                        "  -> {name}: dense/sorf = {:.2}x {} | scalar/batch = {:.2}x {}",
                        t_dense / t_batch.max(1e-12),
                        if t_batch < t_dense { "(sorf wins)" } else { "(dense wins)" },
                        t_scalar / t_batch.max(1e-12),
                        if t_batch <= t_scalar { "(batch >= 1x)" } else { "(REGRESSION)" }
                    );
                }
            }
        }
    }
    println!(
        "\nacceptance: (1) opu/gauss at k=5 (d=25), m >= 2048 — sorf must beat dense \
         (blocks of p=32, 3·log2(32) butterflies/element vs 25 madds/element); \
         (2) the batch-major path must be >= 1x the row-at-a-time path at every \
         (d, m, batch) point: {}",
        if batch_never_loses { "HELD on this run" } else { "VIOLATED on this run" }
    );
    match log.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
}
