//! Bench: Fig 2 (right) — per-subgraph computation time vs k for every
//! feature map (phi_match, phi_Gs, phi_Gs+eig, phi_OPU simulated on CPU
//! and over PJRT, and the physical-OPU analytic model).
//!
//! Paper shape to reproduce: phi_match exponential in k, Gaussian maps
//! polynomial, OPU constant. Results also land in results/fig2_right.json.
//!
//! Run: `cargo bench --bench fig2_right_time` (add
//! `BENCH_M=5000 BENCH_POOL=512 BENCH_KS=3,4,5,6,7,8` to override).

#[allow(dead_code)]
mod bench_harness;

use graphlet_rf::experiments::{timing, ExpContext};
use graphlet_rf::runtime::{artifacts_dir, Engine};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let m = env_usize("BENCH_M", 5000);
    let pool = env_usize("BENCH_POOL", 256);
    let ks: Vec<usize> = std::env::var("BENCH_KS")
        .map(|v| v.split(',').filter_map(|s| s.parse().ok()).collect())
        .unwrap_or_else(|_| vec![3, 4, 5, 6, 7, 8]);

    let engine = Engine::new(&artifacts_dir()).ok();
    if engine.is_none() {
        eprintln!("note: no artifacts — PJRT series skipped (run `make artifacts`)");
    }
    let ctx = ExpContext::new(engine, std::path::PathBuf::from("results"));
    let out = timing::fig2_right(&ctx, &ks, m, pool).expect("fig2_right");
    // Criterion-style per-series lines for the bench log.
    let json = out.to_string();
    println!("\n(bench json written to results/fig2_right.json, {} bytes)", json.len());
}
