//! Bench: Table 1 — empirical verification of the per-graph GSA-phi
//! complexities:
//!
//!   phi_match    O(C_S s N_k C_k)   — exponential in k
//!   phi_Gs       O(C_S s m k^2)     — linear in m, quadratic-ish in k
//!   phi_Gs+eig   O(C_S s (mk+k^3))  — linear in m, cheaper in k
//!   phi_OPU      O(C_S s)           — constant per projection (physical)
//!
//! Measures scaling in BOTH k (fixed m) and m (fixed k) and prints the
//! fitted rates next to the theoretical ones.

mod bench_harness;

use bench_harness::{bench_case, BenchLog};
use graphlet_rf::features::{CpuFeatureMap, RfParams, Variant};
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::iso::GraphletRegistry;
use graphlet_rf::sample::{GraphletSampler, UniformSampler};
use graphlet_rf::util::Rng;

fn pool(k: usize, n: usize, seed: u64) -> Vec<graphlet_rf::graph::Graphlet> {
    let g = SbmConfig::default().sample_graph(1, &mut Rng::new(seed));
    let mut rng = Rng::new(seed ^ 1);
    let mut scratch = Vec::new();
    (0..n).map(|_| UniformSampler.sample(&g, k, &mut rng, &mut scratch)).collect()
}

fn fit_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var.max(1e-300)
}

fn main() {
    let n = 256usize;
    let mut rng = Rng::new(7);
    let mut log = BenchLog::new("table1_complexity");

    // --- scaling in m at fixed k (phi_Gs and phi_OPU are O(m)) ---------
    println!("# Table 1: scaling in m (k = 6 fixed)");
    let k = 6usize;
    let d = k * k;
    let graphlets = pool(k, n, 11);
    let mut x = vec![0.0f32; n * d];
    for (i, g) in graphlets.iter().enumerate() {
        g.write_flat_adj(&mut x[i * d..(i + 1) * d]);
    }
    for variant in [Variant::Gauss, Variant::Opu] {
        let (mut lms, mut lts) = (Vec::new(), Vec::new());
        for m in [250usize, 1000, 4000] {
            let params = RfParams::generate(variant, d, m, 0.1, &mut rng);
            let map = CpuFeatureMap::new(params);
            let mut y = vec![0.0f32; n * m];
            let name = format!("{}_m{m}", variant.name());
            let t = bench_case("table1_m", &name, 1, 5, || {
                map.map_batch(&x, n, &mut y);
            });
            log.record("table1_m", &name, t);
            lms.push((m as f64).ln());
            lts.push(t.max(1e-12).ln());
        }
        println!("  -> {} m-exponent: {:.2} (theory: 1.0)", variant.name(), fit_slope(&lms, &lts));
    }

    // --- scaling in k at fixed m ----------------------------------------
    println!("\n# Table 1: scaling in k (m = 2000 fixed)");
    let m = 2000usize;
    // phi_match: time per classify (exponential).
    let (mut ks_f, mut lt_match) = (Vec::new(), Vec::new());
    for k in [4usize, 5, 6, 7, 8] {
        let graphlets = pool(k, n, 23 + k as u64);
        let mut reg = GraphletRegistry::new();
        let name = format!("match_k{k}");
        let t = bench_case("table1_k", &name, 1, 3, || {
            for g in &graphlets {
                std::hint::black_box(reg.classify(g));
            }
        });
        log.record("table1_k", &name, t);
        ks_f.push(k as f64);
        lt_match.push((t / n as f64).max(1e-12).ln());
    }
    println!("  -> match log-time slope per k: {:.2} (exponential => > 0.3)", fit_slope(&ks_f, &lt_match));

    for variant in [Variant::Gauss, Variant::Opu] {
        let (mut lks, mut lts) = (Vec::new(), Vec::new());
        for k in [4usize, 6, 8] {
            let d = k * k;
            let graphlets = pool(k, n, 31 + k as u64);
            let mut x = vec![0.0f32; n * d];
            for (i, g) in graphlets.iter().enumerate() {
                g.write_flat_adj(&mut x[i * d..(i + 1) * d]);
            }
            let params = RfParams::generate(variant, d, m, 0.1, &mut rng);
            let map = CpuFeatureMap::new(params);
            let mut y = vec![0.0f32; n * m];
            let name = format!("{}_k{k}", variant.name());
            let t = bench_case("table1_k", &name, 1, 5, || {
                map.map_batch(&x, n, &mut y);
            });
            log.record("table1_k", &name, t);
            lks.push((k as f64).ln());
            lts.push(t.max(1e-12).ln());
        }
        println!(
            "  -> {} k-degree: {:.2} (theory: ~2 for adjacency input)",
            variant.name(),
            fit_slope(&lks, &lts)
        );
    }

    // Physical OPU: constant by the device model.
    println!(
        "\nphysical OPU model: {} per projection for ANY k, m (constant)",
        bench_harness::fmt(graphlet_rf::features::OPU_SECONDS_PER_PROJECTION)
    );

    match log.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
}
